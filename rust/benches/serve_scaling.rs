//! serve_scaling — multi-tenant server throughput and tail latency vs
//! drain-worker count.
//!
//! Artifact-free: hosts three deterministic synthetic models
//! (`model::synth`) behind the real registry/batcher/loadgen stack on
//! the **gatesim** backend — per-batch inference is real work (netlist
//! simulation), so one worker saturates and the sweep measures drain
//! scaling rather than the load generator.  Reports req/s, worst-model
//! p50/p99, and shed counts at 1..N workers.  Expected shape: shed
//! falls and p99 drops as workers are added until the offered rate (or
//! the core count) is absorbed; accuracy pins at 1.000 (self-labeled
//! splits + bit-exact backend — any other value is a correctness bug,
//! not noise).

mod harness;

use std::time::Duration;

use printed_mlp::data::ArtifactStore;
use printed_mlp::runtime::Backend;
use printed_mlp::server::{self, ArchKind, CampaignConfig, Scenario, ServeConfig, SloClass};
use printed_mlp::util::json::{num, obj, s, Json};
use printed_mlp::util::pool;

fn main() {
    harness::section(
        "serve_scaling — req/s and p99 vs workers (3 synthetic models, gatesim, steady)",
    );
    let store = ArtifactStore::discover(); // unused in synthetic mode
    let max_workers = pool::default_threads();
    let mut workers = 1usize;
    let mut counts = Vec::new();
    while workers <= max_workers {
        counts.push(workers);
        workers *= 2;
    }
    if counts.last() != Some(&max_workers) {
        counts.push(max_workers);
    }
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>8} {:>6} {:>8}",
        "workers", "req/s", "p50 ms", "p99 ms", "shed", "fill", "acc"
    );
    let mut rows: Vec<Json> = Vec::new();
    for &w in &counts {
        let cfg = ServeConfig {
            datasets: vec!["syn0".into(), "syn1".into(), "syn2".into()],
            scenario: Scenario::Steady,
            rate_hz: 8_000.0,
            duration: Duration::from_millis(400),
            sensors: 4,
            workers: w,
            queue_cap: 8192,
            backend: Backend::GateSim,
            synthetic: true,
            ..ServeConfig::default()
        };
        let rep = server::run(&store, &cfg).expect("synthetic serve run");
        let p50 = rep.models.iter().map(|m| m.p50_ms).fold(0.0f64, f64::max);
        let p99 = rep.models.iter().map(|m| m.p99_ms).fold(0.0f64, f64::max);
        let acc = rep.models.iter().map(|m| m.accuracy).fold(1.0f64, f64::min);
        let fill = rep.models.iter().map(|m| m.fill).fold(1.0f64, f64::min);
        println!(
            "{:>8} {:>10.0} {:>10.2} {:>10.2} {:>8} {:>6.2} {:>8.3}",
            w,
            rep.total_rps(),
            p50,
            p99,
            rep.total_shed(),
            fill,
            acc
        );
        assert_eq!(acc, 1.0, "synthetic serving must stay bit-exact");
        rows.push(obj(vec![
            ("workers", num(w as f64)),
            ("rps", num(rep.total_rps())),
            ("p50_ms", num(p50)),
            ("p99_ms", num(p99)),
            ("shed", num(rep.total_shed() as f64)),
            ("fill", num(fill)),
            ("accuracy", num(acc)),
        ]));
    }
    println!(
        "\n(worst per-model p50/p99 and fill shown; shed >0 means the offered rate \
         beat the pool; fill <1 means partial super-lane blocks at the linger tail)"
    );

    // TCP ingress: the same synthetic registry behind real loopback
    // sockets, three tenants spread across the three SLO classes, offered
    // well past what one worker absorbs so the admission ceilings bite and
    // bronze sheds first.  Open-loop clients time each frame from its
    // *scheduled* send instant (coordinated-omission correct), so the
    // per-class p99 stays honest under saturation.  A mid-run hot reload
    // with a full canary is compared against a no-reload control run to
    // quantify the reload blip.
    harness::section(
        "serve_scaling — TCP ingress: per-class SLO under overload, hot-reload blip",
    );
    let tcp_cfg = |reload: Option<Duration>| ServeConfig {
        datasets: vec!["gold0".into(), "silver0".into(), "bronze0".into()],
        classes: vec![SloClass::Gold, SloClass::Silver, SloClass::Bronze],
        scenario: Scenario::Steady,
        rate_hz: 6_000.0,
        duration: Duration::from_millis(500),
        sensors: 3,
        workers: 1,
        queue_cap: 256,
        slo_ms: 50.0,
        shed_late: true,
        backend: Backend::GateSim,
        synthetic: true,
        listen: Some("127.0.0.1:0".into()),
        reload_at: reload,
        canary_frac: if reload.is_some() { 1.0 } else { 0.0 },
        ..ServeConfig::default()
    };
    let control = server::run(&store, &tcp_cfg(None)).expect("tcp control run");
    let reloaded = server::run(&store, &tcp_cfg(Some(Duration::from_millis(200))))
        .expect("tcp reload run");
    println!(
        "{:>8} {:>10} {:>10} {:>8} {:>8} {:>10} {:>10}",
        "class", "requests", "answered", "shed", "late", "p50 ms", "p99 ms"
    );
    let mut class_rows_json: Vec<Json> = Vec::new();
    for row in reloaded.class_rows() {
        let p50 = reloaded
            .models
            .iter()
            .filter(|m| m.class == row.class)
            .map(|m| m.p50_ms)
            .fold(0.0f64, f64::max);
        println!(
            "{:>8} {:>10} {:>10} {:>8} {:>8} {:>10.2} {:>10.2}",
            row.class.label(),
            row.requests,
            row.answered,
            row.shed,
            row.late,
            p50,
            row.p99_ms
        );
        class_rows_json.push(obj(vec![
            ("class", s(row.class.label())),
            ("requests", num(row.requests as f64)),
            ("answered", num(row.answered as f64)),
            ("shed", num(row.shed as f64)),
            ("late", num(row.late as f64)),
            ("slo_violations", num(row.slo_violations as f64)),
            ("p50_ms", num(p50)),
            ("p99_ms", num(row.p99_ms)),
        ]));
    }
    for run in [&control, &reloaded] {
        let ing = run.ingress.as_ref().expect("tcp run reports ingress");
        assert_eq!(
            ing.client_lost, 0,
            "socket exactly-once: every accepted frame answered, even through reload"
        );
        assert_eq!(run.total_errors(), 0, "overload sheds, it must not error");
    }
    let mismatches: usize = reloaded.models.iter().map(|m| m.canary_mismatches).sum();
    assert_eq!(mismatches, 0, "identical rebuild must agree with its incumbent");
    let checked: usize = reloaded.models.iter().map(|m| m.canary_checked).sum();
    let worst_p99 =
        |r: &server::ServerReport| r.models.iter().map(|m| m.p99_ms).fold(0.0f64, f64::max);
    let (p99_ctl, p99_rel) = (worst_p99(&control), worst_p99(&reloaded));
    println!(
        "\nreload blip: worst p99 {p99_ctl:.2} ms (no reload) -> {p99_rel:.2} ms \
         (reload + full canary), {checked} frames shadowed, 0 mismatches, 0 lost"
    );
    let reload_json = obj(vec![
        ("p99_ms_no_reload", num(p99_ctl)),
        ("p99_ms_reload", num(p99_rel)),
        ("blip_ms", num(p99_rel - p99_ctl)),
        ("canary_checked", num(checked as f64)),
        ("canary_mismatches", num(mismatches as f64)),
        ("client_lost", num(0.0)),
        (
            "version",
            num(reloaded.models.iter().map(|m| m.version).max().unwrap_or(1) as f64),
        ),
    ]);

    // Fault-campaign rows: the same synthetic registry under the stuck-at /
    // transient sweep, per architecture.  Degradation comes from the full
    // deterministic split pass; p99/SLO from the served traffic.
    harness::section("serve_scaling — fault campaign (ours/hybrid/comb, 0:0 and 8:2)");
    let campaign = CampaignConfig {
        serve: ServeConfig {
            datasets: vec!["syn0".into(), "syn1".into(), "syn2".into()],
            scenario: Scenario::Steady,
            rate_hz: 4_000.0,
            duration: Duration::from_millis(150),
            sensors: 4,
            workers: max_workers,
            queue_cap: 8192,
            backend: Backend::GateSim,
            synthetic: true,
            ..ServeConfig::default()
        },
        archs: vec![ArchKind::Ours, ArchKind::Hybrid, ArchKind::Comb],
        levels: vec![(0, 0), (8, 2)],
        ..CampaignConfig::default()
    };
    let rep = server::campaign::run_campaign(&store, &campaign).expect("fault campaign");
    println!(
        "{:>7} {:>6} {:>6} {:>6} {:>10} {:>10} {:>8} {:>9}",
        "arch", "model", "stuck", "flips", "clean acc", "fault acc", "p99 ms", "slo viol"
    );
    let mut fault_rows: Vec<Json> = Vec::new();
    for row in &rep.rows {
        println!(
            "{:>7} {:>6} {:>6} {:>6} {:>10.3} {:>10.3} {:>8.2} {:>9}",
            row.arch.label(),
            row.model,
            row.stuck,
            row.transient,
            row.baseline_accuracy,
            row.fault_accuracy,
            row.serve.p99_ms,
            row.serve.slo_violations
        );
        if row.stuck == 0 && row.transient == 0 {
            assert_eq!(
                row.degradation, 0.0,
                "zero-fault campaign cell must match the clean pass bit-for-bit"
            );
        }
        fault_rows.push(obj(vec![
            ("arch", s(row.arch.label())),
            ("model", s(&row.model)),
            ("stuck", num(row.stuck as f64)),
            ("transient", num(row.transient as f64)),
            ("flip_rate", num(row.flip_rate)),
            ("baseline_accuracy", num(row.baseline_accuracy)),
            ("fault_accuracy", num(row.fault_accuracy)),
            ("degradation", num(row.degradation)),
            ("p99_ms", num(row.serve.p99_ms)),
            ("slo_violations", num(row.serve.slo_violations as f64)),
            ("errors", num(row.serve.errors as f64)),
            ("shed", num(row.serve.shed as f64)),
        ]));
    }

    // Fused serving: the fan-in scenario submits one small frame per
    // model per sensor window — the worst case for per-model batching
    // (three ragged queues, partial super-lane blocks everywhere).
    // --fuse-models concatenates the three compiled plans and drains all
    // queues through one simulator pass per sweep, so the tenants share
    // lane fill.  Accuracy pins at 1.000 either way (bit-identical per
    // tests/server_batching.rs); the interesting deltas are fill, p99,
    // and req/s.
    harness::section("serve_scaling — fan-in: fused (one plan, all tenants) vs per-model drain");
    let fanin_cfg = |fuse: bool| ServeConfig {
        datasets: vec!["syn0".into(), "syn1".into(), "syn2".into()],
        scenario: Scenario::FanIn,
        rate_hz: 3_000.0,
        duration: Duration::from_millis(400),
        sensors: 4,
        workers: 2,
        queue_cap: 8192,
        backend: Backend::GateSim,
        synthetic: true,
        fuse_models: fuse,
        seed: 7,
        ..ServeConfig::default()
    };
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>8} {:>6} {:>8}",
        "drain", "req/s", "p50 ms", "p99 ms", "shed", "fill", "acc"
    );
    let mut fused_rows: Vec<Json> = Vec::new();
    for fuse in [false, true] {
        let rep = server::run(&store, &fanin_cfg(fuse)).expect("fan-in serve run");
        let p50 = rep.models.iter().map(|m| m.p50_ms).fold(0.0f64, f64::max);
        let p99 = rep.models.iter().map(|m| m.p99_ms).fold(0.0f64, f64::max);
        let acc = rep.models.iter().map(|m| m.accuracy).fold(1.0f64, f64::min);
        let fill = rep.models.iter().map(|m| m.fill).fold(1.0f64, f64::min);
        let label = if fuse { "fused" } else { "per-model" };
        println!(
            "{:>10} {:>10.0} {:>10.2} {:>10.2} {:>8} {:>6.2} {:>8.3}",
            label,
            rep.total_rps(),
            p50,
            p99,
            rep.total_shed(),
            fill,
            acc
        );
        assert_eq!(acc, 1.0, "fan-in serving must stay bit-exact (fused={fuse})");
        fused_rows.push(obj(vec![
            ("drain", s(label)),
            ("scenario", s("fanin")),
            ("workers", num(2.0)),
            ("rps", num(rep.total_rps())),
            ("p50_ms", num(p50)),
            ("p99_ms", num(p99)),
            ("shed", num(rep.total_shed() as f64)),
            ("fill", num(fill)),
            ("accuracy", num(acc)),
        ]));
    }

    harness::write_results_json(
        "BENCH_serve.json",
        &obj(vec![
            ("bench", s("serve_scaling")),
            ("backend", s("gatesim")),
            ("scenario", s("steady")),
            ("rows", Json::Arr(rows)),
            ("ingress_class_rows", Json::Arr(class_rows_json)),
            ("reload", reload_json),
            ("fault_rows", Json::Arr(fault_rows)),
            ("fused_rows", Json::Arr(fused_rows)),
        ]),
    );
}
