//! Figure 7 regeneration: hybrid (NSGA-II-approximated) vs multi-cycle
//! sequential at 1%/2%/5% accuracy-drop budgets — plus the NSGA fitness
//! evaluation throughput (the framework's dominant cost).
//!
//! The pipeline outcomes behind the table run the parallel, memoized
//! NSGA path end to end whenever the resolved backend is native (the CI
//! case under the vendored xla stub — see DESIGN.md §Perf); the perf
//! sections below measure that path directly, then the PJRT serial
//! fitness loop when a real client is available.

mod harness;

use printed_mlp::approx;
use printed_mlp::model::ApproxTables;
use printed_mlp::nsga::NsgaConfig;
use printed_mlp::report;
use printed_mlp::runtime::{PjrtEvaluator, BATCH_THROUGHPUT};
use printed_mlp::util::pool;

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    harness::section("Figure 7 — neuron approximation (hybrid vs multi-cycle)");
    let outs = harness::pipeline_outcomes(&store);
    let md = report::fig7(&outs, &store.results_dir()).expect("fig7");
    println!("{md}");

    let name = "har";
    let m = store.model(name).unwrap();
    let ds = store.dataset(name).unwrap();
    let fit = ds.train.head(512);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &fit.xs, fit.len(), &fm);
    let cfg = NsgaConfig {
        pop_size: 12,
        generations: 8,
        ..Default::default()
    };

    // Perf: the parallel, memoized NSGA search on the native model —
    // artifact-gated but PJRT-free, so it runs under the vendored stub.
    let threads = pool::default_threads();
    for t in [1usize, threads] {
        harness::bench(
            &format!("NSGA pop12×gen8 native parallel, {t:>2} thread(s) (har)"),
            3,
            || {
                let (front, _) = approx::explore_parallel(&m, &fit, &fm, &tables, &cfg, t);
                std::hint::black_box(front.len());
            },
        );
    }
    let (_, stats) = approx::explore_parallel(&m, &fit, &fm, &tables, &cfg, threads);
    println!(
        "  memo: {} unique evals / {} requested ({:.0}% hit rate)",
        stats.evals,
        stats.requested,
        100.0 * stats.hit_rate()
    );

    // Perf: one NSGA fitness evaluation = one masked PJRT accuracy pass.
    // Needs a PJRT client; skipped (with a note) under the vendored stub.
    let Some(engine) = harness::require_pjrt() else { return };
    let eval = PjrtEvaluator::new(
        &engine,
        &store.hlo_path(name, BATCH_THROUGHPUT),
        &m,
        BATCH_THROUGHPUT,
    )
    .unwrap();
    let am = vec![1u8; m.hidden];
    harness::bench("NSGA fitness eval: PJRT 512 samples (har)", 20, || {
        std::hint::black_box(eval.accuracy(&fit, &fm, &am, &tables).unwrap());
    });

    // Perf: a full small NSGA run end-to-end on the serial PJRT path.
    harness::bench("NSGA pop12×gen8 PJRT serial (har)", 3, || {
        let front = approx::explore(m.hidden, &cfg, |mask| {
            eval.accuracy(&fit, &fm, mask, &tables).unwrap()
        });
        std::hint::black_box(front.len());
    });
    let _ = ApproxTables::disabled(1);
}
