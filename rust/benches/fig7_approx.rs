//! Figure 7 regeneration: hybrid (NSGA-II-approximated) vs multi-cycle
//! sequential at 1%/2%/5% accuracy-drop budgets — plus the NSGA fitness
//! evaluation throughput (the framework's dominant cost).

mod harness;

use printed_mlp::approx;
use printed_mlp::model::ApproxTables;
use printed_mlp::nsga::NsgaConfig;
use printed_mlp::report;
use printed_mlp::runtime::{PjrtEvaluator, BATCH_THROUGHPUT};

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    harness::section("Figure 7 — neuron approximation (hybrid vs multi-cycle)");
    let outs = harness::pipeline_outcomes(&store);
    let md = report::fig7(&outs, &store.results_dir()).expect("fig7");
    println!("{md}");

    // Perf: one NSGA fitness evaluation = one masked PJRT accuracy pass.
    // Needs a PJRT client; skipped (with a note) under the vendored stub.
    let Some(engine) = harness::require_pjrt() else { return };
    let name = "har";
    let m = store.model(name).unwrap();
    let ds = store.dataset(name).unwrap();
    let eval = PjrtEvaluator::new(
        &engine,
        &store.hlo_path(name, BATCH_THROUGHPUT),
        &m,
        BATCH_THROUGHPUT,
    )
    .unwrap();
    let fit = ds.train.head(512);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &fit.xs, fit.len(), &fm);
    let am = vec![1u8; m.hidden];
    harness::bench("NSGA fitness eval: PJRT 512 samples (har)", 20, || {
        std::hint::black_box(eval.accuracy(&fit, &fm, &am, &tables).unwrap());
    });

    // Perf: a full small NSGA run end-to-end.
    harness::bench("NSGA pop12×gen8 end-to-end (har)", 3, || {
        let cfg = NsgaConfig {
            pop_size: 12,
            generations: 8,
            ..Default::default()
        };
        let front = approx::explore(m.hidden, &cfg, |mask| {
            eval.accuracy(&fit, &fm, mask, &tables).unwrap()
        });
        std::hint::black_box(front.len());
    });
    let _ = ApproxTables::disabled(1);
}
