//! Table 1 regeneration: accuracy, [16] area/power, and the proposed
//! design's area/power gains per dataset — plus gate-level simulation
//! throughput (the VCS-substitute's hot path).

mod harness;

use printed_mlp::circuits::seq_multicycle;
use printed_mlp::report;
use printed_mlp::sim::testbench;

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    harness::section("Table 1 — accuracy, area, power (paper vs measured)");
    let outs = harness::pipeline_outcomes(&store);
    let md = report::table1(&outs, &store.results_dir()).expect("table1");
    println!("{md}");

    // Perf: gate-level accuracy evaluation (full test set) per dataset.
    for name in ["spectf", "gas"] {
        let m = store.model(name).unwrap();
        let ds = store.dataset(name).unwrap();
        let active: Vec<usize> = (0..m.features).collect();
        let circ = seq_multicycle::generate(&m, &active);
        harness::bench(
            &format!("gate-level sim, full test set ({name})"),
            5,
            || {
                let preds =
                    testbench::run_sequential(&circ, &ds.test.xs, ds.test.len(), m.features);
                std::hint::black_box(testbench::accuracy(&preds, &ds.test.ys));
            },
        );
    }
}
