//! Sharded gate-level simulation throughput: compiled (micro-op stream)
//! vs interpreted (levelized `Vec<Cell>` walk) plans at 1..N threads on a
//! seq_multicycle circuit — gate-evals/sec, thread-scaling speedup, the
//! compiled-vs-interpreted speedup at every thread count, and the one-off
//! plan-compile cost.
//!
//! Artifact-free — the circuit comes from a random `QuantModel` — so this
//! bench always runs, unlike the `make artifacts`-gated harnesses.  The
//! acceptance bars: >= 2x throughput at 4+ threads vs 1 thread on
//! multi-core hosts (sharding), and > 1.0x single-thread compiled vs
//! interpreted (plan compilation); both paths are bit-identical
//! (tests/sim_compiled.rs, tests/sim_sharding.rs).

mod harness;
#[path = "../tests/common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use common::rand_model;
use printed_mlp::circuits::seq_multicycle;
use printed_mlp::sim::{batch, testbench, SimPlan};
use printed_mlp::util::pool;
use printed_mlp::util::prng::Rng;

fn main() {
    harness::section("Sim sharding — seq_multicycle gate-evals/sec vs threads");

    // HAR-class circuit: 48 active features, 16 hidden, 5 classes.
    let m = rand_model(11, 48, 16, 5);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let n = 4096usize;
    let mut rng = Rng::new(3);
    let xs: Vec<u8> = (0..n * m.features).map(|_| rng.below(16) as u8).collect();

    // Plans: the interpreted oracle and the compiled micro-op stream,
    // with the one-off compile cost measured.
    let t0 = Instant::now();
    let interp = Arc::new(SimPlan::new(&circ.netlist));
    let levelize_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let compiled = Arc::new(SimPlan::compiled(&circ.netlist));
    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cp = compiled.compiled_plan().expect("compiled plan");

    let cycles = (circ.cycles + 1) as f64; // + reset cycle
    let blocks = batch::n_blocks(n) as f64;
    // Every block evaluates every cell once per cycle across 64 lanes
    // (interpreted-path normalization, so both paths stay comparable with
    // the pre-compilation records).
    let lane_gate_evals = circ.netlist.cells.len() as f64 * cycles * blocks * 64.0;
    println!(
        "circuit: {} cells, {} cycles/inference, {n} samples ({} blocks)",
        circ.netlist.cells.len(),
        circ.cycles + 1,
        batch::n_blocks(n)
    );
    println!(
        "plan: levelize {levelize_ms:.2} ms | compile {compile_ms:.2} ms -> \
         {} micro-ops (of {} comb cells), {} regs, {} dense nets (of {})",
        cp.n_ops(),
        circ.netlist.cells.len() - interp.n_dffs(),
        cp.n_state(),
        cp.n_dense_nets(),
        circ.netlist.n_nets()
    );

    let avail = pool::default_threads();
    let mut thread_counts = vec![1usize, 2, 4];
    if !thread_counts.contains(&avail) {
        thread_counts.push(avail);
    }

    let mut base_ms = [0.0f64; 2]; // [interpreted, compiled] 1-thread means
    for &threads in &thread_counts {
        let mut pair_ms = [0.0f64; 2];
        for (pi, &(label, plan)) in [("interp", &interp), ("compiled", &compiled)]
            .iter()
            .enumerate()
        {
            let r = harness::bench(
                &format!("seq sim {n} samples, {threads:>2} thr, {label}"),
                3,
                || {
                    let preds =
                        testbench::run_sequential_plan(&circ, plan, &xs, n, m.features, threads);
                    std::hint::black_box(preds.len());
                },
            );
            if threads == 1 {
                base_ms[pi] = r.mean_ms;
            }
            pair_ms[pi] = r.mean_ms;
            let speedup = if r.mean_ms > 0.0 { base_ms[pi] / r.mean_ms } else { 0.0 };
            println!(
                "         -> {:8.1} M lane-gate-evals/s | speedup {speedup:4.2}x vs 1 thread",
                lane_gate_evals / r.mean_ms * 1e-3,
            );
        }
        if pair_ms[1] > 0.0 {
            println!(
                "         == compiled is {:4.2}x interpreted at {threads} thread(s)",
                pair_ms[0] / pair_ms[1]
            );
        }
    }
    println!(
        "note: PRINTED_MLP_THREADS caps the default worker count ({avail} here); \
         sharded, serial, compiled and interpreted runs are all bit-identical \
         (tests/sim_sharding.rs, tests/sim_compiled.rs)."
    );
}
