//! Sharded gate-level simulation throughput: super-lane width (W×u64
//! lane blocks + opcode-run kernels) and thread scaling, compiled
//! (micro-op stream) vs interpreted (levelized `Vec<Cell>` walk) plans
//! on seq_multicycle circuits — samples/sec, speedup vs the W=1 compiled
//! path, thread-scaling speedup, the one-off plan-compile cost, and the
//! activity-profiling (per-net toggle counter) overhead.
//!
//! Artifact-free — the circuits come from random `QuantModel`s — so this
//! bench always runs, unlike the `make artifacts`-gated harnesses.  The
//! acceptance bars: >= 2x single-thread samples/s at the best W vs W=1
//! compiled on at least one circuit (super-lanes), >= 2x throughput at
//! 4+ threads vs 1 thread on multi-core hosts (sharding), > 1.0x
//! single-thread compiled vs interpreted at W=1 (plan compilation), and
//! <= 15% slowdown with toggle counters on (activity profiling — the
//! counters-off path is byte-for-byte the PR 5 kernels, so off costs
//! nothing); all paths and widths are bit-identical
//! (tests/sim_compiled.rs W-sweep, tests/sim_sharding.rs,
//! tests/activity_energy.rs).
//!
//! Machine-readable trajectory: every row also lands in
//! `artifacts/results/BENCH_sim.json` so perf regressions are diffable
//! across PRs.

mod harness;
#[path = "../tests/common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use common::rand_model;
use printed_mlp::circuits::seq_multicycle;
use printed_mlp::sim::{testbench, SimPlan, LANE_WORD_CHOICES};
use printed_mlp::util::json::{num, obj, s, Json};
use printed_mlp::util::pool;
use printed_mlp::util::prng::Rng;

fn main() {
    harness::section("Sim throughput — super-lane W sweep + thread scaling (seq_multicycle)");

    // Two circuit scales: a small sensor-class model (hot in L1/L2 even
    // at W=8) and a HAR-class model (48 active features, 16 hidden, 5
    // classes) whose wide value vector stresses cache footprint.
    let shapes: [(&str, u64, usize, usize, usize); 2] =
        [("sensor12x5x3", 7, 12, 5, 3), ("har48x16x5", 11, 48, 16, 5)];
    let n = 4096usize;
    let avail = pool::default_threads();
    let mut rows: Vec<Json> = Vec::new();
    let mut best_speedup = 0.0f64;
    let mut worst_activity_overhead = f64::NEG_INFINITY;
    let mut best_gate_speedup = 0.0f64;

    for (cname, seed, f, h, c) in shapes {
        let m = rand_model(seed, f, h, c);
        let active: Vec<usize> = (0..m.features).collect();
        let circ = seq_multicycle::generate(&m, &active);
        let mut rng = Rng::new(3);
        let xs: Vec<u8> = (0..n * m.features).map(|_| rng.below(16) as u8).collect();

        // Plans: the interpreted oracle and the compiled micro-op stream,
        // with the one-off compile cost measured.
        let t0 = Instant::now();
        let interp = Arc::new(SimPlan::new(&circ.netlist));
        let levelize_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let compiled = Arc::new(SimPlan::compiled(&circ.netlist));
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        let cp = compiled.compiled_plan().expect("compiled plan");

        println!(
            "\n-- {cname}: {} cells, {} cycles/inference, {n} samples",
            circ.netlist.cells.len(),
            circ.cycles + 1
        );
        println!(
            "   plan: levelize {levelize_ms:.2} ms | compile {compile_ms:.2} ms -> \
             {} micro-ops in {} opcode runs ({:.1} ops/run), {} regs, {} dense nets (of {})",
            cp.n_ops(),
            cp.n_runs(),
            cp.n_ops() as f64 / cp.n_runs().max(1) as f64,
            cp.n_state(),
            cp.n_dense_nets(),
            circ.netlist.n_nets()
        );

        // §Super-lane sweep: single thread, compiled at every W (plus the
        // interpreted W=1 oracle for reference).  samples/s is the
        // end-to-end metric the accuracy loops and serve path feel.
        let bench_one =
            |label: &str, path: &str, plan: &Arc<SimPlan>, w: usize, thr: usize| -> (f64, Json) {
                let r = harness::bench(&format!("{cname} {label}"), 3, || {
                    let preds =
                        testbench::run_sequential_plan(&circ, plan, &xs, n, m.features, thr, w);
                    std::hint::black_box(preds.len());
                });
                let sps = n as f64 / r.mean_ms * 1e3;
                println!("         -> {sps:9.0} samples/s");
                let row = obj(vec![
                    ("circuit", s(cname)),
                    ("path", s(path)),
                    ("lane_words", num(w as f64)),
                    ("threads", num(thr as f64)),
                    ("mean_ms", num(r.mean_ms)),
                    ("p50_ms", num(r.p50_ms)),
                    ("p99_ms", num(r.p99_ms)),
                    ("samples_per_s", num(sps)),
                ]);
                (r.mean_ms, row)
            };

        let (interp_ms, row) = bench_one("1thr interp   W=1", "interp", &interp, 1, 1);
        rows.push(row);
        let (base_ms, row) = bench_one("1thr compiled W=1", "compiled", &compiled, 1, 1);
        rows.push(row);
        println!(
            "         == compiled W=1 is {:.2}x interpreted (single thread)",
            interp_ms / base_ms
        );
        for w in LANE_WORD_CHOICES {
            if w == 1 {
                continue;
            }
            let (ms, mut row) =
                bench_one(&format!("1thr compiled W={w}"), "compiled", &compiled, w, 1);
            let speedup = base_ms / ms;
            println!("         == W={w} is {speedup:.2}x the W=1 compiled path");
            if let Json::Obj(map) = &mut row {
                map.insert("speedup_vs_w1".to_string(), num(speedup));
            }
            rows.push(row);
            best_speedup = best_speedup.max(speedup);
        }

        // §Activity profiling overhead: per-net toggle counters on vs
        // off at the auto width, single thread.  Acceptance: <= 15%
        // slowdown with counters on; off is the untouched hot path.
        let w = printed_mlp::sim::lane_words_default();
        let (off_ms, row) =
            bench_one(&format!("1thr compiled W={w} act off"), "compiled", &compiled, w, 1);
        rows.push(row);
        let r = harness::bench(&format!("{cname} 1thr compiled W={w} act ON "), 3, || {
            let (preds, act) = testbench::run_sequential_plan_activity(
                &circ, &compiled, &xs, n, m.features, 1, w, None,
            );
            std::hint::black_box((preds.len(), act.total_toggles()));
        });
        let sps = n as f64 / r.mean_ms * 1e3;
        let overhead = (r.mean_ms / off_ms - 1.0) * 100.0;
        println!(
            "         -> {sps:9.0} samples/s | activity overhead {overhead:+.1}% (bar: <= 15%)"
        );
        rows.push(obj(vec![
            ("circuit", s(cname)),
            ("path", s("compiled+activity")),
            ("lane_words", num(w as f64)),
            ("threads", num(1.0)),
            ("mean_ms", num(r.mean_ms)),
            ("p50_ms", num(r.p50_ms)),
            ("p99_ms", num(r.p99_ms)),
            ("samples_per_s", num(sps)),
            ("activity_overhead_pct", num(overhead)),
        ]));
        worst_activity_overhead = worst_activity_overhead.max(overhead);

        // §Activity gating: skip compiled runs whose input blocks did
        // not toggle.  The sequential protocol holds the feature bus
        // through the drain cycles and settles to a fixpoint, so real
        // work drops out; predictions stay bit-identical
        // (tests/sim_gating.rs).  Reported: speedup vs the ungated
        // compiled path at the same width and the measured skip rate.
        let r = harness::bench(&format!("{cname} 1thr compiled W={w} gated  "), 3, || {
            let (preds, st) = testbench::run_sequential_plan_gated(
                &circ, &compiled, &xs, n, m.features, 1, w, None,
            );
            std::hint::black_box((preds.len(), st.executed));
        });
        let (_, stats) =
            testbench::run_sequential_plan_gated(&circ, &compiled, &xs, n, m.features, 1, w, None);
        let sps = n as f64 / r.mean_ms * 1e3;
        let gate_speedup = off_ms / r.mean_ms;
        println!(
            "         -> {sps:9.0} samples/s | {:.2}x vs ungated | skip rate {:.1}% \
             ({} executed / {} skipped runs)",
            gate_speedup,
            stats.skip_rate() * 100.0,
            stats.executed,
            stats.skipped
        );
        rows.push(obj(vec![
            ("circuit", s(cname)),
            ("path", s("compiled+gated")),
            ("lane_words", num(w as f64)),
            ("threads", num(1.0)),
            ("mean_ms", num(r.mean_ms)),
            ("p50_ms", num(r.p50_ms)),
            ("p99_ms", num(r.p99_ms)),
            ("samples_per_s", num(sps)),
            ("speedup_vs_ungated", num(gate_speedup)),
            ("skip_rate", num(stats.skip_rate())),
        ]));
        best_gate_speedup = best_gate_speedup.max(gate_speedup);

        // Thread scaling on the HAR-class circuit at the auto-picked
        // width (reusing this iteration's plan and stimulus) — shows
        // super-lanes and sharding stack.
        if cname != "har48x16x5" {
            continue;
        }
        let mut thread_counts = vec![1usize, 2, 4];
        if !thread_counts.contains(&avail) {
            thread_counts.push(avail);
        }
        println!("   thread scaling at auto W={w}:");
        let mut base_ms = 0.0f64;
        for &threads in &thread_counts {
            let r = harness::bench(&format!("{cname} {threads:>2} thr compiled W={w}"), 3, || {
                let preds =
                    testbench::run_sequential_plan(&circ, &compiled, &xs, n, m.features, threads, w);
                std::hint::black_box(preds.len());
            });
            if threads == 1 {
                base_ms = r.mean_ms;
            }
            let sps = n as f64 / r.mean_ms * 1e3;
            let speedup = if r.mean_ms > 0.0 { base_ms / r.mean_ms } else { 0.0 };
            println!("         -> {sps:9.0} samples/s | speedup {speedup:4.2}x vs 1 thread");
            rows.push(obj(vec![
                ("circuit", s(cname)),
                ("path", s("compiled")),
                ("lane_words", num(w as f64)),
                ("threads", num(threads as f64)),
                ("mean_ms", num(r.mean_ms)),
                ("p50_ms", num(r.p50_ms)),
                ("p99_ms", num(r.p99_ms)),
                ("samples_per_s", num(sps)),
            ]));
        }
    }

    println!(
        "\nbest super-lane speedup vs W=1 compiled (single thread): {best_speedup:.2}x \
         (acceptance bar: >= 2x on at least one circuit)"
    );
    println!(
        "worst activity-profiling overhead (counters on vs off, single thread): \
         {worst_activity_overhead:+.1}% (acceptance bar: <= 15%; counters off = untouched path)"
    );
    println!(
        "best activity-gating speedup vs ungated compiled (single thread): \
         {best_gate_speedup:.2}x (opt-in via --gate-activity; bit-identical per \
         tests/sim_gating.rs)"
    );
    println!(
        "note: PRINTED_MLP_THREADS caps the default worker count ({avail} here) and \
         PRINTED_MLP_SIM_LANES / --sim-lanes pins the width; sharded, serial, wide, \
         compiled and interpreted runs are all bit-identical \
         (tests/sim_sharding.rs, tests/sim_compiled.rs)."
    );
    harness::write_results_json(
        "BENCH_sim.json",
        &obj(vec![
            ("bench", s("sim_throughput")),
            ("samples", num(n as f64)),
            ("best_w_speedup_vs_w1", num(best_speedup)),
            ("worst_activity_overhead_pct", num(worst_activity_overhead)),
            ("best_gate_speedup_vs_ungated", num(best_gate_speedup)),
            ("rows", Json::Arr(rows)),
        ]),
    );
}
