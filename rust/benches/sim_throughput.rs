//! Sharded gate-level simulation throughput: 1 thread vs N threads on a
//! seq_multicycle circuit (gate-evals/sec and speedup), plus the serial
//! overhead of plan reuse.
//!
//! Artifact-free — the circuit comes from a random `QuantModel` — so this
//! bench always runs, unlike the `make artifacts`-gated harnesses.  The
//! acceptance bar for the sharding subsystem is >= 2x throughput at 4+
//! threads vs 1 thread on multi-core hosts.

mod harness;
#[path = "../tests/common/mod.rs"]
mod common;

use common::rand_model;
use printed_mlp::circuits::seq_multicycle;
use printed_mlp::sim::{batch, testbench};
use printed_mlp::util::pool;
use printed_mlp::util::prng::Rng;

fn main() {
    harness::section("Sim sharding — seq_multicycle gate-evals/sec vs threads");

    // HAR-class circuit: 48 active features, 16 hidden, 5 classes.
    let m = rand_model(11, 48, 16, 5);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let n = 4096usize;
    let mut rng = Rng::new(3);
    let xs: Vec<u8> = (0..n * m.features).map(|_| rng.below(16) as u8).collect();

    let cycles = (circ.cycles + 1) as f64; // + reset cycle
    let blocks = batch::n_blocks(n) as f64;
    // Every block evaluates every cell once per cycle across 64 lanes.
    let lane_gate_evals = circ.netlist.cells.len() as f64 * cycles * blocks * 64.0;
    println!(
        "circuit: {} cells, {} cycles/inference, {n} samples ({} blocks)",
        circ.netlist.cells.len(),
        circ.cycles + 1,
        batch::n_blocks(n)
    );

    let avail = pool::default_threads();
    let mut thread_counts = vec![1usize, 2, 4];
    if !thread_counts.contains(&avail) {
        thread_counts.push(avail);
    }

    let mut base_ms = 0.0f64;
    for &threads in &thread_counts {
        let r = harness::bench(
            &format!("seq sim {n} samples, {threads:>2} thread(s)"),
            3,
            || {
                let preds = testbench::run_sequential_threads(&circ, &xs, n, m.features, threads);
                std::hint::black_box(preds.len());
            },
        );
        if threads == 1 {
            base_ms = r.mean_ms;
        }
        let speedup = if r.mean_ms > 0.0 { base_ms / r.mean_ms } else { 0.0 };
        println!(
            "         -> {:8.1} M lane-gate-evals/s | speedup {speedup:4.2}x vs 1 thread",
            lane_gate_evals / r.mean_ms * 1e-3,
        );
    }
    println!(
        "note: PRINTED_MLP_THREADS caps the default worker count ({avail} here); \
         the sharded and 1-thread runs are bit-identical (tests/sim_sharding.rs)."
    );
}
