//! Ablation studies for the design choices DESIGN.md calls out:
//!
//!   A1  RFP on/off — what feature pruning buys in area, cycles, energy
//!   A2  base realignment on/off — the single-cycle neuron's hardwired
//!       expected-value constant (§3.1.2 "realign"), measured as accuracy
//!       when approximating each dataset's single best neuron
//!   A3  netlist optimizer (CSE+DCE) contribution to the hardwired designs
//!   A4  RFP search strategy — greedy (paper) vs bisect (§Perf), evals
//!   A5  NSGA memo cache on/off — unique fitness evaluations, hit rate,
//!       and wall-clock on the parallel native search path (§Perf)
//!
//! Run with `cargo bench --bench ablations`.

mod harness;

use printed_mlp::approx;
use printed_mlp::circuits::seq_multicycle;
use printed_mlp::model::ApproxTables;
use printed_mlp::nsga::NsgaConfig;
use printed_mlp::rfp::{self, Strategy};
use printed_mlp::runtime::{PjrtEvaluator, BATCH_THROUGHPUT};
use printed_mlp::tech;
use printed_mlp::util::pool;

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    // A1 and A4 drive RFP through PJRT; under the vendored xla stub they
    // are skipped (with a note) while A2/A3 still run.
    let engine = harness::require_pjrt();

    // --- A1: RFP on/off ------------------------------------------------------
    if let Some(engine) = &engine {
        harness::section("A1 — RFP on vs off (multi-cycle design)");
        println!(
            "{:>12} {:>6} {:>6} {:>11} {:>11} {:>10}",
            "dataset", "F", "kept", "area off", "area on", "Δcycles"
        );
        for name in ["spectf", "gas", "har"] {
            let m = store.model(name).unwrap();
            let ds = store.dataset(name).unwrap();
            let eval = PjrtEvaluator::new(
                engine,
                &store.hlo_path(name, BATCH_THROUGHPUT),
                &m,
                BATCH_THROUGHPUT,
            )
            .unwrap();
            let fit = ds.train.head(512);
            let prep = eval.prepare(&fit).unwrap();
            let am = vec![0u8; m.hidden];
            let t = ApproxTables::disabled(m.hidden);
            let thr = eval
                .accuracy_prepared(&prep, &vec![1u8; m.features], &am, &t)
                .unwrap();
            let res = rfp::prune(&m, &fit, thr, Strategy::Bisect, |mask| {
                eval.accuracy_prepared(&prep, mask, &am, &t).unwrap()
            });
            let all: Vec<usize> = (0..m.features).collect();
            let off = tech::report(&seq_multicycle::generate(&m, &all).netlist);
            let on = tech::report(&seq_multicycle::generate(&m, &res.active).netlist);
            println!(
                "{name:>12} {:>6} {:>6} {:>9.1} c {:>9.1} c {:>10}",
                m.features,
                res.kept,
                off.area_cm2,
                on.area_cm2,
                m.features - res.kept
            );
        }
    }

    // --- A2: base realignment on/off ----------------------------------------
    harness::section("A2 — single-cycle base realignment (accuracy, best 1-neuron approx)");
    println!("{:>12} {:>10} {:>14} {:>14}", "dataset", "exact", "aligned", "bias-only");
    for name in ["spectf", "gas", "har"] {
        let m = store.model(name).unwrap();
        let ds = store.dataset(name).unwrap();
        let fit = ds.train.head(512);
        let fm = vec![1u8; m.features];
        let tables = printed_mlp::approx::build_tables(&m, &fit.xs, fit.len(), &fm);
        // Strawman tables: base = raw bias (no expectation realignment).
        let mut naive = tables.clone();
        for h in 0..m.hidden {
            naive.base[h] = m.b1[h];
        }
        let am0 = vec![0u8; m.hidden];
        let exact = m.accuracy(&fit.xs, &fit.ys, &fm, &am0, &tables);
        let (mut best_al, mut best_nv) = (0.0f64, 0.0f64);
        for h in 0..m.hidden {
            let mut am = vec![0u8; m.hidden];
            am[h] = 1;
            best_al = best_al.max(m.accuracy(&fit.xs, &fit.ys, &fm, &am, &tables));
            best_nv = best_nv.max(m.accuracy(&fit.xs, &fit.ys, &fm, &am, &naive));
        }
        println!("{name:>12} {exact:>10.3} {best_al:>14.3} {best_nv:>14.3}");
    }

    // --- A3: netlist optimizer contribution ---------------------------------
    harness::section("A3 — CSE+DCE contribution (multi-cycle, const-folded hardwiring)");
    println!("{:>12} {:>12} {:>12} {:>8}", "dataset", "raw cells", "opt cells", "ratio");
    for name in ["spectf", "arrhythmia"] {
        let m = store.model(name).unwrap();
        let active: Vec<usize> = (0..m.features).collect();
        let circ = seq_multicycle::generate(&m, &active);
        let opt_cells = circ.netlist.cells.len();
        println!(
            "{name:>12} {:>12} {:>12} {:>8.2}",
            circ.raw_cells,
            opt_cells,
            circ.raw_cells as f64 / opt_cells.max(1) as f64
        );
    }

    // --- A4: RFP strategy evals ----------------------------------------------
    if let Some(engine) = &engine {
        harness::section("A4 — RFP evals: greedy (paper) vs bisect (§Perf)");
        println!("{:>12} {:>8} {:>8} {:>9} {:>9}", "dataset", "g.evals", "b.evals", "g.kept", "b.kept");
        for name in ["spectf", "gas", "epileptic"] {
            let m = store.model(name).unwrap();
            let ds = store.dataset(name).unwrap();
            let eval = PjrtEvaluator::new(
                engine,
                &store.hlo_path(name, BATCH_THROUGHPUT),
                &m,
                BATCH_THROUGHPUT,
            )
            .unwrap();
            let fit = ds.train.head(512);
            let prep = eval.prepare(&fit).unwrap();
            let am = vec![0u8; m.hidden];
            let t = ApproxTables::disabled(m.hidden);
            let thr = eval
                .accuracy_prepared(&prep, &vec![1u8; m.features], &am, &t)
                .unwrap();
            let run = |s: Strategy| {
                rfp::prune(&m, &fit, thr, s, |mask| {
                    eval.accuracy_prepared(&prep, mask, &am, &t).unwrap()
                })
            };
            let g = run(Strategy::Greedy);
            let b = run(Strategy::Bisect);
            println!(
                "{name:>12} {:>8} {:>8} {:>9} {:>9}",
                g.evals, b.evals, g.kept, b.kept
            );
        }
    }

    // --- A5: NSGA memo cache on/off -----------------------------------------
    // Parallel native search path (PJRT-free): what the genome memo saves
    // in unique fitness evaluations and wall-clock, fronts bit-identical.
    harness::section("A5 — NSGA memo cache on vs off (native parallel, pop 16 × gen 10)");
    let threads = pool::default_threads();
    println!(
        "{:>12} {:>10} {:>10} {:>9} {:>9}",
        "dataset", "evals off", "evals on", "hit rate", "speedup"
    );
    for name in ["spectf", "gas"] {
        let m = store.model(name).unwrap();
        let ds = store.dataset(name).unwrap();
        let fit = ds.train.head(256);
        let fm = vec![1u8; m.features];
        let tables = approx::build_tables(&m, &fit.xs, fit.len(), &fm);
        let mut cfg = NsgaConfig {
            pop_size: 16,
            generations: 10,
            ..Default::default()
        };
        cfg.memoize = false;
        let t0 = std::time::Instant::now();
        let (front_off, off) = approx::explore_parallel(&m, &fit, &fm, &tables, &cfg, threads);
        let secs_off = t0.elapsed().as_secs_f64();
        cfg.memoize = true;
        let t1 = std::time::Instant::now();
        let (front_on, on) = approx::explore_parallel(&m, &fit, &fm, &tables, &cfg, threads);
        let secs_on = t1.elapsed().as_secs_f64();
        assert_eq!(front_off.len(), front_on.len(), "memo must not change the front");
        for (a, b) in front_off.iter().zip(&front_on) {
            assert_eq!(a.genome, b.genome, "memo must not change the front");
            assert_eq!(a.objectives, b.objectives, "memo must not change the front");
        }
        println!(
            "{name:>12} {:>10} {:>10} {:>8.0}% {:>8.2}x",
            off.evals,
            on.evals,
            100.0 * on.hit_rate(),
            secs_off / secs_on.max(1e-9)
        );
    }
}
