//! Figure 6 regeneration: area and power of the combinational [14],
//! conventional sequential [16], and proposed multi-cycle designs over
//! all seven datasets (QAT + RFP applied to all, as in §4.2.1), plus the
//! end-to-end timing of the synthesis-lite flow per architecture.

mod harness;

use printed_mlp::report;

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    harness::section("Figure 6 — area & power across architectures");
    let outs = harness::pipeline_outcomes(&store);
    let md = report::fig6(&outs, &store.results_dir()).expect("fig6");
    println!("{md}");

    // Perf: full characterize (generate + optimize + cost) per arch.
    let m = store.model("gas").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    harness::bench("comb generate+cost (gas, 128F)", 5, || {
        let c = printed_mlp::circuits::combinational::generate(&m, &active);
        std::hint::black_box(printed_mlp::tech::report(&c.netlist).area_cm2);
    });
    harness::bench("seq_sota generate+cost (gas)", 5, || {
        let c = printed_mlp::circuits::seq_sota::generate(&m, &active);
        std::hint::black_box(printed_mlp::tech::report(&c.netlist).area_cm2);
    });
    harness::bench("multicycle generate+cost (gas)", 5, || {
        let c = printed_mlp::circuits::seq_multicycle::generate(&m, &active);
        std::hint::black_box(printed_mlp::tech::report(&c.netlist).area_cm2);
    });
}
