//! Shared support for integration tests and benches: a deterministic
//! random [`QuantModel`] builder.  The crate-internal
//! `circuits::testutil::rand_model` is `#[cfg(test)]`-gated and therefore
//! invisible to integration tests and benches, so the external harnesses
//! share this one instead of each carrying a copy.

#![allow(dead_code)]

use printed_mlp::model::QuantModel;
use printed_mlp::util::prng::Rng;

/// Random valid pow2-quantized model (signs in {-1,0,1}, powers in
/// [0, pmax]); fully determined by `seed`.
pub fn rand_model(seed: u64, features: usize, hidden: usize, classes: usize) -> QuantModel {
    let mut r = Rng::new(seed);
    let pmax = 6u32;
    let mut w1p = vec![0i32; hidden * features];
    let mut w1s = vec![0i32; hidden * features];
    for i in 0..hidden * features {
        w1p[i] = r.below(pmax as u64 + 1) as i32;
        w1s[i] = [-1, 0, 1][r.usize_below(3)];
    }
    let mut w2p = vec![0i32; classes * hidden];
    let mut w2s = vec![0i32; classes * hidden];
    for i in 0..classes * hidden {
        w2p[i] = r.below(pmax as u64 + 1) as i32;
        w2s[i] = [-1, 0, 1][r.usize_below(3)];
    }
    QuantModel {
        name: format!("rand{seed}"),
        features,
        classes,
        hidden,
        in_bits: 4,
        w_bits: 8,
        pmax,
        trunc: (r.below(6) + 1) as u32,
        seq_clock_ms: 100.0,
        comb_clock_ms: 320.0,
        float_acc: 0.0,
        train_acc: 0.0,
        test_acc: 0.0,
        w1p,
        w1s,
        b1: (0..hidden).map(|_| r.i32_range(-300, 300)).collect(),
        w2p,
        w2s,
        b2: (0..classes).map(|_| r.i32_range(-300, 300)).collect(),
    }
}
