//! Shared support for integration tests and benches.
//!
//! The deterministic random-model builder now lives in the library
//! (`printed_mlp::model::synth`, which also feeds `serve --synthetic` and
//! the `serve_scaling` bench); this shim keeps the historical
//! `common::rand_model` import path for the external harnesses.  Values
//! are bit-identical to the pre-move generator at equal seeds.

#![allow(dead_code)]
#![allow(unused_imports)]

pub use printed_mlp::model::synth::rand_model;
