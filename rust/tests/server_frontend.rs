//! Tier-1 loopback tests for the TCP ingress (`server::frontend`) and
//! the hot-reload/canary path, artifact-free via synthetic registries:
//!
//! - a socket round trip is bit-identical to a direct
//!   [`Evaluator::predict`] call on the same rows;
//! - a slow client dribbling one byte at a time is still answered
//!   (partial frames reassemble; the read deadline only fires on stalls);
//! - an oversized length prefix or bad magic loses only that connection
//!   — the accept loop survives and a fresh connection is served;
//! - unknown models and wrong-shape feature vectors are `Refused` on a
//!   connection that stays open;
//! - the canary counts incumbent/candidate disagreements exactly on a
//!   deliberately divergent same-shape candidate, off the response path;
//! - a full `serve_with` run over TCP with a mid-run hot reload answers
//!   every accepted frame (zero client-side losses), promotes every
//!   slot to version 2, and records zero canary mismatches for an
//!   identical rebuild.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use printed_mlp::data::ArtifactStore;
use printed_mlp::model::synth;
use printed_mlp::runtime::{owned_evaluator, Backend, EvalOpts};
use printed_mlp::server::frontend::{decode_response, encode_request, Request, MAX_FRAME};
use printed_mlp::server::{
    self, batcher, BatchQueue, DrainConfig, Frame, Frontend, ModelEntry, ModelRegistry, Scenario,
    Status,
};

fn synthetic_registry(n: usize, seed: u64) -> ModelRegistry {
    let names: Vec<String> = (0..n).map(|i| format!("net{i}")).collect();
    ModelRegistry::synthetic(&names, seed)
}

/// Read one length-prefixed response frame off a blocking socket.
fn read_response(stream: &mut TcpStream) -> printed_mlp::server::frontend::Response {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).expect("response length prefix");
    let n = u32::from_le_bytes(len) as usize;
    let mut payload = vec![0u8; n];
    stream.read_exact(&mut payload).expect("response payload");
    decode_response(&payload).expect("well-formed response frame")
}

/// Run `client` against a live frontend + batcher, then drain both.
/// Returns after both server threads have exited cleanly.
fn with_server<T>(
    reg: &ModelRegistry,
    dcfg: &DrainConfig,
    client: impl FnOnce(&Frontend, std::net::SocketAddr) -> T,
) -> T {
    let slots = reg.slots(Backend::Native, 1, 0, &[]).unwrap();
    let queues: Vec<BatchQueue> = reg.entries().iter().map(|_| BatchQueue::new(4096)).collect();
    let frontend = Frontend::bind("127.0.0.1:0").unwrap();
    let addr = frontend.local_addr();
    let fe_stop = AtomicBool::new(false);
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let fe_h = s.spawn(|| frontend.run(&slots, &queues, &fe_stop));
        let dr_h = s.spawn(|| batcher::drain(&queues, &slots, dcfg, &stop));
        let out = client(&frontend, addr);
        // Drain order mirrors serve_with: stop reading, answer
        // everything owed, then let the workers empty the queues.
        fe_stop.store(true, Ordering::Release);
        stop.store(true, Ordering::Release);
        fe_h.join().unwrap().expect("frontend exits cleanly");
        dr_h.join().unwrap().expect("batcher exits cleanly");
        out
    })
}

fn quick_drain() -> DrainConfig {
    DrainConfig {
        workers: 2,
        batch: 16,
        max_wait: Duration::from_micros(200),
        slo_ms: 1e9,
        ..DrainConfig::default()
    }
}

#[test]
fn tcp_round_trip_is_bit_identical_to_direct_predict() {
    let reg = synthetic_registry(2, 71);
    let entries = reg.entries().to_vec();
    let got = with_server(&reg, &quick_drain(), |_, addr| {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).ok();
        // Interleave both models; features are the split's own rows so
        // the server-side answer has a computable ground truth.
        let mut sent: Vec<(u64, usize, usize)> = Vec::new();
        for i in 0..50u64 {
            let m = (i % 2) as usize;
            let sample = i as usize % entries[m].test.len();
            let req = Request {
                model: m as u16,
                id: i,
                features: entries[m].test.row(sample).to_vec(),
            };
            stream.write_all(&encode_request(&req)).unwrap();
            sent.push((i, m, sample));
        }
        let mut got = Vec::new();
        for _ in 0..sent.len() {
            got.push(read_response(&mut stream));
        }
        (sent, got)
    });
    let (sent, responses) = got;
    assert_eq!(responses.len(), 50, "every request answered exactly once");

    // Ground truth: direct predict over the same rows, per model.
    let opts = EvalOpts::default();
    let mut want: std::collections::HashMap<u64, i32> = std::collections::HashMap::new();
    for (m, entry) in entries.iter().enumerate() {
        let rows: Vec<(u64, usize)> = sent
            .iter()
            .filter(|&&(_, mm, _)| mm == m)
            .map(|&(id, _, sample)| (id, sample))
            .collect();
        let mut xs = Vec::new();
        for &(_, sample) in &rows {
            xs.extend_from_slice(entry.test.row(sample));
        }
        let eval = owned_evaluator(Backend::Native, &entry.model, &opts).unwrap();
        let preds = eval
            .predict(&xs, rows.len(), &entry.feat_mask, &entry.approx_mask, &entry.tables)
            .unwrap();
        for (&(id, _), &p) in rows.iter().zip(&preds) {
            want.insert(id, p);
        }
    }
    for resp in &responses {
        assert_eq!(resp.status, Status::Ok, "frame {}: must be served", resp.id);
        assert_eq!(
            resp.pred, want[&resp.id],
            "frame {}: socket answer must be bit-identical to direct predict",
            resp.id
        );
    }
}

#[test]
fn slow_byte_by_byte_writer_is_still_answered() {
    let reg = synthetic_registry(1, 73);
    let entry = Arc::clone(&reg.entries()[0]);
    let resp = with_server(&reg, &quick_drain(), |_, addr| {
        let mut stream = TcpStream::connect(addr).unwrap();
        let req = Request {
            model: 0,
            id: 9001,
            features: entry.test.row(3).to_vec(),
        };
        // Dribble the frame one byte at a time, well inside the read
        // deadline: the frontend must reassemble, not give up.
        for b in encode_request(&req) {
            stream.write_all(&[b]).unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        read_response(&mut stream)
    });
    assert_eq!(resp.id, 9001);
    assert_eq!(resp.status, Status::Ok);
}

#[test]
fn malformed_frames_lose_only_their_connection() {
    let reg = synthetic_registry(1, 77);
    let entry = Arc::clone(&reg.entries()[0]);
    with_server(&reg, &quick_drain(), |fe, addr| {
        // Oversized length prefix: fatal for this connection.
        let mut bad = TcpStream::connect(addr).unwrap();
        bad.write_all(&((MAX_FRAME + 1) as u32).to_le_bytes()).unwrap();
        let mut byte = [0u8; 1];
        let closed = matches!(bad.read(&mut byte), Ok(0) | Err(_));
        assert!(closed, "oversized frame must close the connection");

        // Valid length, bad magic: also fatal for this connection.
        let mut bad = TcpStream::connect(addr).unwrap();
        let mut wire = encode_request(&Request {
            model: 0,
            id: 1,
            features: entry.test.row(0).to_vec(),
        });
        wire[4] ^= 0xFF; // corrupt the magic inside the payload
        bad.write_all(&wire).unwrap();
        let closed = matches!(bad.read(&mut byte), Ok(0) | Err(_));
        assert!(closed, "bad magic must close the connection");

        // The accept loop survived both: a fresh connection is served.
        let mut good = TcpStream::connect(addr).unwrap();
        good.write_all(&encode_request(&Request {
            model: 0,
            id: 2,
            features: entry.test.row(1).to_vec(),
        }))
        .unwrap();
        let resp = read_response(&mut good);
        assert_eq!(resp.id, 2);
        assert_eq!(resp.status, Status::Ok);
        assert!(
            fe.stats.malformed.load(Ordering::Relaxed) >= 2,
            "both poison frames counted as malformed"
        );
    });
}

#[test]
fn unknown_model_and_bad_shape_are_refused_without_closing() {
    let reg = synthetic_registry(1, 79);
    let entry = Arc::clone(&reg.entries()[0]);
    with_server(&reg, &quick_drain(), |fe, addr| {
        let mut stream = TcpStream::connect(addr).unwrap();
        // Unknown model id.
        stream
            .write_all(&encode_request(&Request {
                model: 99,
                id: 1,
                features: entry.test.row(0).to_vec(),
            }))
            .unwrap();
        let resp = read_response(&mut stream);
        assert_eq!(resp.status, Status::Refused);
        assert_eq!(resp.pred, -1);
        // Wrong feature count for a known model.
        stream
            .write_all(&encode_request(&Request {
                model: 0,
                id: 2,
                features: vec![1; entry.model.features + 1],
            }))
            .unwrap();
        let resp = read_response(&mut stream);
        assert_eq!(resp.status, Status::Refused);
        // The same connection still serves valid traffic afterwards.
        stream
            .write_all(&encode_request(&Request {
                model: 0,
                id: 3,
                features: entry.test.row(2).to_vec(),
            }))
            .unwrap();
        let resp = read_response(&mut stream);
        assert_eq!(resp.id, 3);
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(fe.stats.refused.load(Ordering::Relaxed), 2);
        assert_eq!(fe.stats.malformed.load(Ordering::Relaxed), 0);
    });
}

#[test]
fn canary_counts_divergent_candidate_mismatches_exactly() {
    let reg = synthetic_registry(1, 83);
    let slots = reg.slots(Backend::Native, 1, 0, &[]).unwrap();
    let slot = &slots[0];
    let entry = Arc::clone(&slot.current().entry);
    let opts = EvalOpts::default();

    // A deliberately divergent candidate with the *same shape* (so the
    // canary's shape guard admits it) but different random weights,
    // sharing the incumbent's test split for a computable ground truth.
    let m = &entry.model;
    let cand_model = synth::rand_model(0xD1FF, m.features, m.hidden, m.classes);
    let cand_entry = Arc::new(ModelEntry::full_precision(
        "net0-cand",
        cand_model.clone(),
        entry.test.clone(),
    ));
    let n = entry.test.len();
    let incumbent_eval = owned_evaluator(Backend::Native, &entry.model, &opts).unwrap();
    let cand_eval = owned_evaluator(Backend::Native, &cand_model, &opts).unwrap();
    let inc_preds = incumbent_eval
        .predict(&entry.test.xs, n, &entry.feat_mask, &entry.approx_mask, &entry.tables)
        .unwrap();
    let cand_preds = cand_eval
        .predict(
            &entry.test.xs,
            n,
            &cand_entry.feat_mask,
            &cand_entry.approx_mask,
            &cand_entry.tables,
        )
        .unwrap();
    let expected_mismatches = inc_preds
        .iter()
        .zip(&cand_preds)
        .filter(|(a, b)| a != b)
        .count();

    let staged_eval = owned_evaluator(Backend::Native, &cand_model, &opts).unwrap();
    let v = slot.stage(Arc::clone(&cand_entry), staged_eval).unwrap();
    assert_eq!(v, 2);
    assert_eq!(slot.version(), 1, "staging leaves the incumbent serving");

    // One frame per test row, shadowing every batch (canary_frac 1.0).
    let queues = vec![BatchQueue::new(4096)];
    for i in 0..n {
        assert!(queues[0].push(Frame::new(i as u64, i)));
    }
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 1,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        canary_step: batcher::canary_step(1.0),
        collect_responses: true,
        ..DrainConfig::default()
    };
    batcher::drain(&queues, &slots, &cfg, &stop).unwrap();

    let st = &queues[0].stats;
    assert_eq!(st.answered.load(Ordering::Relaxed), n);
    assert_eq!(
        st.canary_checked.load(Ordering::Relaxed),
        n,
        "canary_frac 1.0 shadows every frame"
    );
    assert_eq!(
        st.canary_mismatches.load(Ordering::Relaxed),
        expected_mismatches,
        "mismatch counter must equal the precomputed disagreement count"
    );
    // Clients were answered from the incumbent, never the candidate.
    let responses = st.responses.lock().unwrap().clone();
    for &(id, pred) in &responses {
        assert_eq!(
            pred, inc_preds[id as usize],
            "frame {id}: canary shadowing must stay off the response path"
        );
    }
    assert_eq!(slot.version(), 1, "shadowing alone never promotes");
    assert!(slot.promote());
    assert_eq!(slot.version(), 2);
    assert!(slot.candidate().is_none(), "promote consumes the candidate");
}

#[test]
fn tcp_serve_with_hot_reload_answers_every_accepted_frame() {
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = server::ServeConfig {
        datasets: vec!["net0".into(), "net1".into()],
        scenario: Scenario::Steady,
        rate_hz: 400.0,
        duration: Duration::from_millis(400),
        sensors: 2,
        workers: 2,
        queue_cap: 4096,
        backend: Backend::Native,
        synthetic: true,
        seed: 29,
        listen: Some("127.0.0.1:0".into()),
        reload_at: Some(Duration::from_millis(100)),
        canary_frac: 1.0,
        ..server::ServeConfig::default()
    };
    let rep = server::run(&store, &cfg).unwrap();

    let ing = rep.ingress.as_ref().expect("TCP run must report ingress");
    assert!(ing.connections >= cfg.sensors, "one connection per sensor");
    assert_eq!(ing.malformed, 0);
    assert_eq!(ing.refused, 0);
    assert_eq!(
        ing.client_lost, 0,
        "exactly-once across the socket: every accepted frame answered"
    );
    assert_eq!(
        ing.client_sent, ing.client_answered,
        "client ledger balances: sent == answered when nothing is lost"
    );
    assert!(ing.client_sent > 0, "the open-loop clients offered traffic");
    assert_eq!(ing.frames_in, ing.client_sent, "no frame lost in framing");

    assert_eq!(rep.total_errors(), 0);
    assert_eq!(rep.total_shed(), 0, "this rate is far below capacity");
    for m in &rep.models {
        assert_eq!(
            m.version, 2,
            "{}: the mid-run reload must promote every slot",
            m.name
        );
        assert_eq!(
            m.canary_mismatches, 0,
            "{}: an identical rebuild must agree with its incumbent",
            m.name
        );
        assert!(m.answered > 0, "{}: traffic reached the model", m.name);
        assert_eq!(
            m.accuracy, 1.0,
            "{}: client-side scoring sees bit-exact answers throughout the reload",
            m.name
        );
    }
}
