//! Batcher correctness for the multi-tenant model server
//! (`printed_mlp::server`), artifact-free via synthetic registries:
//!
//! - every submitted (non-shed) frame is answered exactly once, through
//!   drain-to-exit;
//! - batched predictions are bit-identical to a direct
//!   [`Evaluator::predict`] call on the same rows;
//! - shedding triggers exactly at the admission ceiling and nowhere
//!   else, and per-class ceilings shed bronze before silver before gold;
//! - deadline shedding (`shed_late`) refuses expired frames *before*
//!   the evaluator sees them, with an exact `late` count;
//! - the steady scenario at a modest rate serves ≥ 3 models end-to-end
//!   with zero shed and accuracy 1.0 (self-labeled splits + exact
//!   backend ⇒ accuracy is a bit-exactness check);
//! - fan-in feeds every hosted model the same window count;
//! - a failing batch is charged to `ModelStats::errors`, the pool keeps
//!   draining sibling queues, and the first error surfaces after the
//!   join (exactly-once: submitted = answered + shed + late + errors);
//! - under 2× overload a gold/bronze pair sheds bronze first while gold
//!   stays inside its SLO.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

use printed_mlp::data::ArtifactStore;
use printed_mlp::runtime::{owned_evaluator, Backend, EvalOpts, Evaluator};
use printed_mlp::server::{
    self, batcher, BatchQueue, DrainConfig, Frame, ModelRegistry, ModelSlot, Scenario, SloClass,
};
use printed_mlp::util::prng::Rng;

fn synthetic_registry(n: usize, seed: u64) -> ModelRegistry {
    let names: Vec<String> = (0..n).map(|i| format!("syn{i}")).collect();
    ModelRegistry::synthetic(&names, seed)
}

#[test]
fn every_frame_answered_exactly_once_and_bit_identical() {
    let reg = synthetic_registry(3, 21);
    let slots = reg.slots(Backend::Native, 1, 0, &[]).unwrap();
    let entries = reg.entries();
    let queues: Vec<BatchQueue> = entries.iter().map(|_| BatchQueue::new(4096)).collect();

    // Push a known frame stream: ids are globally unique, samples random.
    let mut rng = Rng::new(5);
    let mut pushed: Vec<Vec<(u64, usize)>> = vec![Vec::new(); entries.len()];
    let mut next_id = 0u64;
    for _ in 0..400 {
        let m = rng.usize_below(entries.len());
        let sample = rng.usize_below(entries[m].test.len());
        let ok = queues[m].push(Frame::new(next_id, sample));
        assert!(ok, "queue far below capacity must accept");
        pushed[m].push((next_id, sample));
        next_id += 1;
    }

    // Drain to exit: stop is already set, so workers force-pop and quit
    // once the queues are empty.
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 4,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        collect_responses: true,
        ..DrainConfig::default()
    };
    batcher::drain(&queues, &slots, &cfg, &stop).unwrap();

    for (m, queue) in queues.iter().enumerate() {
        let mut responses = queue.stats.responses.lock().unwrap().clone();
        assert_eq!(
            responses.len(),
            pushed[m].len(),
            "model {m}: every frame answered exactly once"
        );
        responses.sort_by_key(|&(id, _)| id);
        let mut ids: Vec<u64> = responses.iter().map(|&(id, _)| id).collect();
        ids.dedup();
        assert_eq!(ids.len(), responses.len(), "model {m}: duplicate answers");

        // Bit-identical to a direct predict on the same rows.
        let entry = &entries[m];
        let f = entry.model.features;
        let mut xs = Vec::with_capacity(pushed[m].len() * f);
        for &(_, sample) in &pushed[m] {
            xs.extend_from_slice(entry.test.row(sample));
        }
        let ver = slots[m].current();
        let want = ver
            .eval
            .predict(&xs, pushed[m].len(), &entry.feat_mask, &entry.approx_mask, &entry.tables)
            .unwrap();
        // `pushed` is in id order per model, `responses` sorted by id.
        for (i, (&(id, _), &(rid, pred))) in
            pushed[m].iter().zip(responses.iter()).enumerate()
        {
            assert_eq!(id, rid, "model {m}: response ids track pushed ids");
            assert_eq!(pred, want[i], "model {m} frame {id}: prediction diverges");
        }
    }
}

#[test]
fn shedding_triggers_exactly_at_capacity() {
    let cap = 4;
    let q = BatchQueue::new(cap);
    for id in 0..cap as u64 {
        assert!(q.push(Frame::new(id, 0)), "below capacity must accept");
    }
    assert_eq!(q.stats.shed.load(std::sync::atomic::Ordering::Relaxed), 0);
    // One over: shed, and only that one.
    assert!(!q.push(Frame::new(99, 0)));
    assert_eq!(q.stats.shed.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(q.len(), cap);
    // Draining frees capacity again.
    let mut out = Vec::new();
    assert_eq!(q.pop_batch(cap, Duration::ZERO, true, &mut out), cap);
    assert!(q.push(Frame::new(100, 0)), "post-drain push must succeed");
    assert_eq!(q.stats.shed.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(
        q.stats.submitted.load(std::sync::atomic::Ordering::Relaxed),
        cap + 2
    );
}

#[test]
fn admission_ceilings_shed_bronze_before_silver_before_gold() {
    use std::sync::atomic::Ordering;
    let cap = 32;
    for (class, want_admit) in [
        (SloClass::Gold, 32),
        (SloClass::Silver, 24),
        (SloClass::Bronze, 16),
    ] {
        let q = BatchQueue::with_admission(cap, class.admit_limit(cap));
        let mut accepted = 0;
        for id in 0..40u64 {
            if q.push(Frame::new(id, 0)) {
                accepted += 1;
            }
        }
        assert_eq!(
            accepted, want_admit,
            "{}: admission ceiling is deterministic",
            class.label()
        );
        assert_eq!(q.stats.shed.load(Ordering::Relaxed), 40 - want_admit);
        assert_eq!(q.stats.submitted.load(Ordering::Relaxed), 40);
    }
}

#[test]
fn subfull_batches_linger_until_max_wait_or_force() {
    let q = BatchQueue::new(64);
    for id in 0..3 {
        q.push(Frame::new(id, 0));
    }
    let mut out = Vec::new();
    // Fresh + sub-full + long linger: held back.
    assert_eq!(q.pop_batch(8, Duration::from_secs(600), false, &mut out), 0);
    // Force (server draining): released.
    assert_eq!(q.pop_batch(8, Duration::from_secs(600), true, &mut out), 3);
    // A full batch never lingers.
    for id in 0..8 {
        q.push(Frame::new(id, 0));
    }
    out.clear();
    assert_eq!(q.pop_batch(8, Duration::from_secs(600), false, &mut out), 8);
}

#[test]
fn gatesim_drain_aligns_batches_to_super_lane_blocks() {
    use std::sync::atomic::Ordering;
    // W=1 gatesim reports a 64-sample block quantum; a deep queue with a
    // small configured batch must drain in whole blocks (batch ceiling
    // rounded up), leaving only the forced tail partial.
    let reg = synthetic_registry(1, 31);
    let slots = reg.slots(Backend::GateSim, 1, 1, &[]).unwrap();
    assert_eq!(slots[0].current().eval.batch_quantum(), 64);
    let entries = reg.entries();
    let queues: Vec<BatchQueue> = entries.iter().map(|_| BatchQueue::new(4096)).collect();
    let mut rng = Rng::new(7);
    for id in 0..200u64 {
        let sample = rng.usize_below(entries[0].test.len());
        assert!(queues[0].push(Frame::new(id, sample)));
    }
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 1,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        ..DrainConfig::default()
    };
    batcher::drain(&queues, &slots, &cfg, &stop).unwrap();
    let st = &queues[0].stats;
    assert_eq!(st.answered.load(Ordering::Relaxed), 200);
    assert_eq!(
        st.batches.load(Ordering::Relaxed),
        4,
        "200 frames at a 64-aligned ceiling drain as 64+64+64+8"
    );
    assert_eq!(
        st.lane_slots.load(Ordering::Relaxed),
        256,
        "three full blocks plus one partial block of lane slots"
    );
}

#[test]
fn late_frames_never_reach_the_evaluator() {
    use std::sync::atomic::{AtomicUsize, Ordering};

    // Counts every row the backend is actually asked to evaluate.
    struct CountingEval {
        inner: Box<dyn Evaluator + Send + Sync>,
        seen: Arc<AtomicUsize>,
    }
    impl Evaluator for CountingEval {
        fn name(&self) -> &'static str {
            "counting"
        }
        fn predict(
            &self,
            xs: &[u8],
            n: usize,
            feat_mask: &[u8],
            approx_mask: &[u8],
            tables: &printed_mlp::model::ApproxTables,
        ) -> anyhow::Result<Vec<i32>> {
            self.seen.fetch_add(n, Ordering::Relaxed);
            self.inner.predict(xs, n, feat_mask, approx_mask, tables)
        }
    }

    let reg = synthetic_registry(1, 23);
    let entries = reg.entries();
    let seen = Arc::new(AtomicUsize::new(0));
    let eval = Box::new(CountingEval {
        inner: owned_evaluator(Backend::Native, &entries[0].model, &EvalOpts::default()).unwrap(),
        seen: Arc::clone(&seen),
    });
    let slots = vec![Arc::new(ModelSlot::new(
        "aged".into(),
        SloClass::Gold,
        Arc::clone(&entries[0]),
        eval,
    ))];
    let queues = vec![BatchQueue::new(4096)];

    // 20 frames pre-aged far past the SLO, 30 fresh ones.
    let aged = Instant::now().checked_sub(Duration::from_secs(10)).unwrap();
    let rows = entries[0].test.len();
    for id in 0..20u64 {
        assert!(queues[0].push(Frame::at(id, id as usize % rows, aged)));
    }
    for id in 20..50u64 {
        assert!(queues[0].push(Frame::new(id, id as usize % rows)));
    }
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 1,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 50.0,
        shed_late: true,
        collect_responses: true,
        ..DrainConfig::default()
    };
    batcher::drain(&queues, &slots, &cfg, &stop).unwrap();
    let st = &queues[0].stats;
    assert_eq!(st.late.load(Ordering::Relaxed), 20, "every aged frame refused as late");
    assert_eq!(st.answered.load(Ordering::Relaxed), 30);
    assert_eq!(st.errors.load(Ordering::Relaxed), 0);
    assert_eq!(
        seen.load(Ordering::Relaxed),
        30,
        "the evaluator never sees a deadline-shed frame"
    );
    assert_eq!(
        st.responses.lock().unwrap().len(),
        30,
        "late frames answer Late, not a prediction"
    );
}

#[test]
fn steady_three_models_zero_shed_exact_accuracy() {
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = server::ServeConfig {
        datasets: vec!["s0".into(), "s1".into(), "s2".into()],
        scenario: Scenario::Steady,
        rate_hz: 400.0,
        duration: Duration::from_millis(300),
        workers: 2,
        queue_cap: 4096,
        backend: Backend::Native,
        synthetic: true,
        seed: 11,
        ..server::ServeConfig::default()
    };
    let rep = server::run(&store, &cfg).unwrap();
    assert_eq!(rep.backend, "native");
    assert_eq!(rep.models.len(), 3, "hosts three models concurrently");
    assert!(rep.total_answered() > 0, "steady load must serve traffic");
    assert!(rep.ingress.is_none(), "no --listen, no ingress section");
    for m in &rep.models {
        assert_eq!(m.class, SloClass::Gold, "{}: classless defaults to gold", m.name);
        assert_eq!(m.version, 1, "{}: no reload, version stays 1", m.name);
        assert_eq!(m.shed, 0, "{}: steady default rate must not shed", m.name);
        assert_eq!(m.late, 0, "{}: shed_late defaults off", m.name);
        assert_eq!(m.canary_checked, 0, "{}: canary defaults off", m.name);
        assert_eq!(
            m.requests, m.answered,
            "{}: every submitted frame answered",
            m.name
        );
        assert!(m.answered > 0, "{}: round-robin reaches every model", m.name);
        assert_eq!(
            m.accuracy, 1.0,
            "{}: self-labeled split + exact backend ⇒ bit-exact serving",
            m.name
        );
        assert_eq!(
            m.fill, 1.0,
            "{}: scalar backend has quantum 1, so every lane slot is used",
            m.name
        );
    }
}

#[test]
fn failing_batches_are_accounted_and_drain_continues() {
    use std::sync::atomic::{AtomicUsize, Ordering};

    // Wraps a real evaluator and fails every other batch — the shape of
    // a transient backend fault (OOM, poisoned lock, device error).
    struct FlakyEval {
        inner: Box<dyn Evaluator + Send + Sync>,
        calls: AtomicUsize,
    }
    impl Evaluator for FlakyEval {
        fn name(&self) -> &'static str {
            "flaky"
        }
        fn predict(
            &self,
            xs: &[u8],
            n: usize,
            feat_mask: &[u8],
            approx_mask: &[u8],
            tables: &printed_mlp::model::ApproxTables,
        ) -> anyhow::Result<Vec<i32>> {
            if self.calls.fetch_add(1, Ordering::Relaxed) % 2 == 1 {
                anyhow::bail!("injected batch failure");
            }
            self.inner.predict(xs, n, feat_mask, approx_mask, tables)
        }
    }

    let reg = synthetic_registry(2, 17);
    let entries = reg.entries();
    let opts = EvalOpts::default();
    // Model 0 fails every other batch; model 1 stays healthy.
    let flaky = Box::new(FlakyEval {
        inner: owned_evaluator(Backend::Native, &entries[0].model, &opts).unwrap(),
        calls: AtomicUsize::new(0),
    });
    let healthy = owned_evaluator(Backend::Native, &entries[1].model, &opts).unwrap();
    let slots = vec![
        Arc::new(ModelSlot::new(
            entries[0].name.clone(),
            SloClass::Gold,
            Arc::clone(&entries[0]),
            flaky,
        )),
        Arc::new(ModelSlot::new(
            entries[1].name.clone(),
            SloClass::Gold,
            Arc::clone(&entries[1]),
            healthy,
        )),
    ];
    let queues: Vec<BatchQueue> = entries.iter().map(|_| BatchQueue::new(4096)).collect();
    let mut rng = Rng::new(3);
    for id in 0..400u64 {
        let m = (id % 2) as usize;
        let sample = rng.usize_below(entries[m].test.len());
        assert!(queues[m].push(Frame::new(id, sample)));
    }
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 2,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        collect_responses: true,
        ..DrainConfig::default()
    };
    let err = batcher::drain(&queues, &slots, &cfg, &stop)
        .expect_err("the flaky model's first failure must surface after the join");
    assert!(
        format!("{err:#}").contains("injected batch failure"),
        "surfaced error carries the evaluator's cause: {err:#}"
    );

    for q in &queues {
        assert!(q.is_empty(), "drain keeps going past failed batches");
    }
    let flaky_st = &queues[0].stats;
    let answered = flaky_st.answered.load(Ordering::Relaxed);
    let errors = flaky_st.errors.load(Ordering::Relaxed);
    assert!(errors > 0, "some batches failed");
    assert!(answered > 0, "the worker kept draining after a failure");
    assert_eq!(
        answered + errors,
        200,
        "exactly-once: every submitted frame is answered or errored"
    );
    assert_eq!(
        flaky_st.responses.lock().unwrap().len(),
        answered,
        "responses land only for answered frames"
    );
    let healthy_st = &queues[1].stats;
    assert_eq!(healthy_st.errors.load(Ordering::Relaxed), 0);
    assert_eq!(
        healthy_st.answered.load(Ordering::Relaxed),
        200,
        "sibling model fully served despite the failures"
    );
}

#[test]
fn overload_sheds_bronze_before_gold_and_gold_meets_slo() {
    // One slow backend shared shape: each batch costs ~8 ms regardless
    // of size, so throughput is bounded by batches/s and the run is a
    // sustained ~2x overload.  The class separation is structural, not a
    // timing accident: both queues saturate, so every popped batch is
    // bounded by the class's admission ceiling (gold 8, bronze 4 at
    // queue_cap 8) and the gold-first drain moves twice the frames per
    // sweep for gold — bronze's shed count must exceed gold's.
    struct SlowEval {
        inner: Box<dyn Evaluator + Send + Sync>,
        delay: Duration,
    }
    impl Evaluator for SlowEval {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn predict(
            &self,
            xs: &[u8],
            n: usize,
            feat_mask: &[u8],
            approx_mask: &[u8],
            tables: &printed_mlp::model::ApproxTables,
        ) -> anyhow::Result<Vec<i32>> {
            std::thread::sleep(self.delay);
            self.inner.predict(xs, n, feat_mask, approx_mask, tables)
        }
    }

    let reg = synthetic_registry(2, 41);
    let entries = reg.entries();
    let opts = EvalOpts::default();
    let mk = |i: usize, class: SloClass| {
        Arc::new(ModelSlot::new(
            entries[i].name.clone(),
            class,
            Arc::clone(&entries[i]),
            Box::new(SlowEval {
                inner: owned_evaluator(Backend::Native, &entries[i].model, &opts).unwrap(),
                delay: Duration::from_millis(8),
            }),
        ))
    };
    let slots = vec![mk(0, SloClass::Gold), mk(1, SloClass::Bronze)];
    let cfg = server::ServeConfig {
        datasets: vec!["g".into(), "b".into()],
        scenario: Scenario::Steady,
        rate_hz: 2000.0,
        duration: Duration::from_millis(400),
        sensors: 2,
        workers: 1,
        batch: 16,
        queue_cap: 8,
        slo_ms: 300.0,
        shed_late: true,
        backend: Backend::Native,
        synthetic: true,
        seed: 13,
        ..server::ServeConfig::default()
    };
    let rep = server::serve_with(&slots, &cfg).unwrap();
    assert_eq!(rep.models.len(), 2);
    let gold = &rep.models[0];
    let bronze = &rep.models[1];
    assert_eq!(gold.class, SloClass::Gold);
    assert_eq!(bronze.class, SloClass::Bronze);
    for m in &rep.models {
        assert_eq!(m.errors, 0, "{}: overload must not error", m.name);
        assert_eq!(
            m.requests,
            m.answered + m.shed + m.late,
            "{}: exactly-once through overload",
            m.name
        );
        assert!(m.requests > 0 && m.answered > 0, "{}: traffic flowed", m.name);
    }
    assert!(
        bronze.shed + bronze.late > gold.shed + gold.late,
        "bronze must shed first under overload (bronze {} vs gold {})",
        bronze.shed + bronze.late,
        gold.shed + gold.late
    );
    assert!(
        gold.p99_ms <= cfg.slo_ms,
        "gold p99 {:.1} ms must stay inside the {:.0} ms SLO",
        gold.p99_ms,
        cfg.slo_ms
    );
}

#[test]
fn fused_drain_is_bit_identical_to_per_model_drain() {
    // The same frame stream through the per-model drain pool and the
    // fused single-plan drain must produce identical responses: fusion
    // only changes *how* lanes are packed, never what they compute.
    let reg = synthetic_registry(3, 67);
    let slots = reg.slots(Backend::GateSim, 1, 1, &[]).unwrap();
    let entries = reg.entries();
    let make_queues =
        || -> Vec<BatchQueue> { entries.iter().map(|_| BatchQueue::new(4096)).collect() };
    let q_solo = make_queues();
    let q_fused = make_queues();

    // Ragged per-model load (model 0 gets ~3x model 2's traffic) so the
    // fused sweep sees uneven batch sizes per tenant.
    let mut rng = Rng::new(13);
    let mut next_id = 0u64;
    for _ in 0..300 {
        let m = [0, 0, 0, 1, 1, 2][rng.usize_below(6)];
        let sample = rng.usize_below(entries[m].test.len());
        let fr = Frame::new(next_id, sample);
        assert!(q_solo[m].push(fr.clone()));
        assert!(q_fused[m].push(fr));
        next_id += 1;
    }

    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 2,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        collect_responses: true,
        ..DrainConfig::default()
    };
    batcher::drain(&q_solo, &slots, &cfg, &stop).unwrap();
    let fused = server::FusedSlot::new(&slots, 2, 1);
    batcher::drain_fused(&q_fused, &slots, &fused, &cfg, &stop).unwrap();

    for m in 0..entries.len() {
        let mut want = q_solo[m].stats.responses.lock().unwrap().clone();
        let mut got = q_fused[m].stats.responses.lock().unwrap().clone();
        assert!(!want.is_empty(), "model {m}: stream must reach every model");
        want.sort_by_key(|&(id, _)| id);
        got.sort_by_key(|&(id, _)| id);
        assert_eq!(want, got, "model {m}: fused drain diverged from per-model drain");
    }
}

#[test]
fn fanin_feeds_every_model_equally() {
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = server::ServeConfig {
        datasets: vec!["a".into(), "b".into(), "c".into()],
        scenario: Scenario::FanIn,
        rate_hz: 300.0,
        duration: Duration::from_millis(250),
        sensors: 2,
        workers: 2,
        queue_cap: 4096,
        backend: Backend::Native,
        synthetic: true,
        ..server::ServeConfig::default()
    };
    let rep = server::run(&store, &cfg).unwrap();
    assert_eq!(rep.models.len(), 3);
    let first = rep.models[0].requests;
    assert!(first > 0, "fan-in generates traffic");
    for m in &rep.models {
        assert_eq!(
            m.requests, first,
            "fan-in submits one frame per model per window"
        );
        assert_eq!(m.shed, 0);
        assert_eq!(m.requests, m.answered);
        assert_eq!(m.accuracy, 1.0);
    }
}

#[test]
fn fused_fanin_serves_every_model_bit_exactly() {
    // End-to-end fused serving on the fan-in scenario: one gatesim plan
    // hosts all three tenants, and accuracy 1.0 on self-labeled splits
    // is the bit-exactness check (same convention as the steady test).
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = server::ServeConfig {
        datasets: vec!["a".into(), "b".into(), "c".into()],
        scenario: Scenario::FanIn,
        rate_hz: 200.0,
        duration: Duration::from_millis(250),
        sensors: 2,
        workers: 2,
        queue_cap: 4096,
        backend: Backend::GateSim,
        fuse_models: true,
        synthetic: true,
        seed: 9,
        ..server::ServeConfig::default()
    };
    let rep = server::run(&store, &cfg).unwrap();
    assert_eq!(rep.backend, "gatesim");
    assert_eq!(rep.models.len(), 3, "the fused plan hosts every tenant");
    for m in &rep.models {
        assert!(m.answered > 0, "{}: fan-in reaches every fused tenant", m.name);
        assert_eq!(m.shed, 0, "{}: modest fan-in rate must not shed", m.name);
        assert_eq!(m.requests, m.answered, "{}: exactly-once through the fused drain", m.name);
        assert_eq!(
            m.accuracy, 1.0,
            "{}: fused predictions must stay bit-exact",
            m.name
        );
    }
}
