//! Batcher correctness for the multi-tenant model server
//! (`printed_mlp::server`), artifact-free via synthetic registries:
//!
//! - every submitted (non-shed) frame is answered exactly once, through
//!   drain-to-exit;
//! - batched predictions are bit-identical to a direct
//!   [`Evaluator::predict`] call on the same rows;
//! - shedding triggers exactly at queue capacity and nowhere else;
//! - the steady scenario at a modest rate serves ≥ 3 models end-to-end
//!   with zero shed and accuracy 1.0 (self-labeled splits + exact
//!   backend ⇒ accuracy is a bit-exactness check);
//! - fan-in feeds every hosted model the same window count;
//! - a failing batch is charged to `ModelStats::errors`, the pool keeps
//!   draining sibling queues, and the first error surfaces after the
//!   join (exactly-once: submitted = answered + shed + errors).

use std::sync::atomic::AtomicBool;
use std::time::{Duration, Instant};

use printed_mlp::data::ArtifactStore;
use printed_mlp::runtime::{Backend, Evaluator};
use printed_mlp::server::{self, batcher, BatchQueue, DrainConfig, Frame, ModelRegistry, Scenario};
use printed_mlp::util::prng::Rng;

fn synthetic_registry(n: usize, seed: u64) -> ModelRegistry {
    let names: Vec<String> = (0..n).map(|i| format!("syn{i}")).collect();
    ModelRegistry::synthetic(&names, seed)
}

#[test]
fn every_frame_answered_exactly_once_and_bit_identical() {
    let reg = synthetic_registry(3, 21);
    let evals = reg.evaluators(Backend::Native, 1, 0).unwrap();
    let entries = reg.entries();
    let queues: Vec<BatchQueue> = entries.iter().map(|_| BatchQueue::new(4096)).collect();

    // Push a known frame stream: ids are globally unique, samples random.
    let mut rng = Rng::new(5);
    let mut pushed: Vec<Vec<(u64, usize)>> = vec![Vec::new(); entries.len()];
    let mut next_id = 0u64;
    for _ in 0..400 {
        let m = rng.usize_below(entries.len());
        let sample = rng.usize_below(entries[m].test.len());
        let ok = queues[m].push(Frame {
            id: next_id,
            sample,
            enqueued: Instant::now(),
        });
        assert!(ok, "queue far below capacity must accept");
        pushed[m].push((next_id, sample));
        next_id += 1;
    }

    // Drain to exit: stop is already set, so workers force-pop and quit
    // once the queues are empty.
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 4,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        collect_responses: true,
    };
    batcher::drain(&queues, entries, &evals, &cfg, &stop).unwrap();

    for (m, queue) in queues.iter().enumerate() {
        let mut responses = queue.stats.responses.lock().unwrap().clone();
        assert_eq!(
            responses.len(),
            pushed[m].len(),
            "model {m}: every frame answered exactly once"
        );
        responses.sort_by_key(|&(id, _)| id);
        let mut ids: Vec<u64> = responses.iter().map(|&(id, _)| id).collect();
        ids.dedup();
        assert_eq!(ids.len(), responses.len(), "model {m}: duplicate answers");

        // Bit-identical to a direct predict on the same rows.
        let entry = &entries[m];
        let f = entry.model.features;
        let mut xs = Vec::with_capacity(pushed[m].len() * f);
        for &(_, sample) in &pushed[m] {
            xs.extend_from_slice(entry.test.row(sample));
        }
        let want = evals[m]
            .predict(&xs, pushed[m].len(), &entry.feat_mask, &entry.approx_mask, &entry.tables)
            .unwrap();
        // `pushed` is in id order per model, `responses` sorted by id.
        for (i, (&(id, _), &(rid, pred))) in
            pushed[m].iter().zip(responses.iter()).enumerate()
        {
            assert_eq!(id, rid, "model {m}: response ids track pushed ids");
            assert_eq!(pred, want[i], "model {m} frame {id}: prediction diverges");
        }
    }
}

#[test]
fn shedding_triggers_exactly_at_capacity() {
    let cap = 4;
    let q = BatchQueue::new(cap);
    let frame = |id: u64| Frame {
        id,
        sample: 0,
        enqueued: Instant::now(),
    };
    for id in 0..cap as u64 {
        assert!(q.push(frame(id)), "below capacity must accept");
    }
    assert_eq!(q.stats.shed.load(std::sync::atomic::Ordering::Relaxed), 0);
    // One over: shed, and only that one.
    assert!(!q.push(frame(99)));
    assert_eq!(q.stats.shed.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(q.len(), cap);
    // Draining frees capacity again.
    let mut out = Vec::new();
    assert_eq!(q.pop_batch(cap, Duration::ZERO, true, &mut out), cap);
    assert!(q.push(frame(100)), "post-drain push must succeed");
    assert_eq!(q.stats.shed.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(
        q.stats.submitted.load(std::sync::atomic::Ordering::Relaxed),
        cap + 2
    );
}

#[test]
fn subfull_batches_linger_until_max_wait_or_force() {
    let q = BatchQueue::new(64);
    for id in 0..3 {
        q.push(Frame {
            id,
            sample: 0,
            enqueued: Instant::now(),
        });
    }
    let mut out = Vec::new();
    // Fresh + sub-full + long linger: held back.
    assert_eq!(q.pop_batch(8, Duration::from_secs(600), false, &mut out), 0);
    // Force (server draining): released.
    assert_eq!(q.pop_batch(8, Duration::from_secs(600), true, &mut out), 3);
    // A full batch never lingers.
    for id in 0..8 {
        q.push(Frame {
            id,
            sample: 0,
            enqueued: Instant::now(),
        });
    }
    out.clear();
    assert_eq!(q.pop_batch(8, Duration::from_secs(600), false, &mut out), 8);
}

#[test]
fn gatesim_drain_aligns_batches_to_super_lane_blocks() {
    use std::sync::atomic::Ordering;
    // W=1 gatesim reports a 64-sample block quantum; a deep queue with a
    // small configured batch must drain in whole blocks (batch ceiling
    // rounded up), leaving only the forced tail partial.
    let reg = synthetic_registry(1, 31);
    let evals = reg.evaluators(Backend::GateSim, 1, 1).unwrap();
    reg.warmup(&evals).unwrap();
    assert_eq!(evals[0].batch_quantum(), 64);
    let entries = reg.entries();
    let queues: Vec<BatchQueue> = entries.iter().map(|_| BatchQueue::new(4096)).collect();
    let mut rng = Rng::new(7);
    for id in 0..200u64 {
        let sample = rng.usize_below(entries[0].test.len());
        assert!(queues[0].push(Frame {
            id,
            sample,
            enqueued: Instant::now(),
        }));
    }
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 1,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        collect_responses: false,
    };
    batcher::drain(&queues, entries, &evals, &cfg, &stop).unwrap();
    let st = &queues[0].stats;
    assert_eq!(st.answered.load(Ordering::Relaxed), 200);
    assert_eq!(
        st.batches.load(Ordering::Relaxed),
        4,
        "200 frames at a 64-aligned ceiling drain as 64+64+64+8"
    );
    assert_eq!(
        st.lane_slots.load(Ordering::Relaxed),
        256,
        "three full blocks plus one partial block of lane slots"
    );
}

#[test]
fn steady_three_models_zero_shed_exact_accuracy() {
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = server::ServeConfig {
        datasets: vec!["s0".into(), "s1".into(), "s2".into()],
        scenario: Scenario::Steady,
        rate_hz: 400.0,
        duration: Duration::from_millis(300),
        workers: 2,
        queue_cap: 4096,
        backend: Backend::Native,
        synthetic: true,
        seed: 11,
        ..server::ServeConfig::default()
    };
    let rep = server::run(&store, &cfg).unwrap();
    assert_eq!(rep.backend, "native");
    assert_eq!(rep.models.len(), 3, "hosts three models concurrently");
    assert!(rep.total_answered() > 0, "steady load must serve traffic");
    for m in &rep.models {
        assert_eq!(m.shed, 0, "{}: steady default rate must not shed", m.name);
        assert_eq!(
            m.requests, m.answered,
            "{}: every submitted frame answered",
            m.name
        );
        assert!(m.answered > 0, "{}: round-robin reaches every model", m.name);
        assert_eq!(
            m.accuracy, 1.0,
            "{}: self-labeled split + exact backend ⇒ bit-exact serving",
            m.name
        );
        assert_eq!(
            m.fill, 1.0,
            "{}: scalar backend has quantum 1, so every lane slot is used",
            m.name
        );
    }
}

#[test]
fn failing_batches_are_accounted_and_drain_continues() {
    use std::sync::atomic::{AtomicUsize, Ordering};

    // Wraps a real evaluator and fails every other batch — the shape of
    // a transient backend fault (OOM, poisoned lock, device error).
    struct FlakyEval<'a> {
        inner: Box<dyn Evaluator + Send + Sync + 'a>,
        calls: AtomicUsize,
    }
    impl Evaluator for FlakyEval<'_> {
        fn name(&self) -> &'static str {
            "flaky"
        }
        fn predict(
            &self,
            xs: &[u8],
            n: usize,
            feat_mask: &[u8],
            approx_mask: &[u8],
            tables: &printed_mlp::model::ApproxTables,
        ) -> anyhow::Result<Vec<i32>> {
            if self.calls.fetch_add(1, Ordering::Relaxed) % 2 == 1 {
                anyhow::bail!("injected batch failure");
            }
            self.inner.predict(xs, n, feat_mask, approx_mask, tables)
        }
    }

    let reg = synthetic_registry(2, 17);
    let mut inner = reg.evaluators(Backend::Native, 1, 0).unwrap();
    // Model 0 fails every other batch; model 1 stays healthy.
    let healthy = inner.pop().unwrap();
    let flaky: Box<dyn Evaluator + Send + Sync + '_> = Box::new(FlakyEval {
        inner: inner.pop().unwrap(),
        calls: AtomicUsize::new(0),
    });
    let evals = vec![flaky, healthy];
    let entries = reg.entries();
    let queues: Vec<BatchQueue> = entries.iter().map(|_| BatchQueue::new(4096)).collect();
    let mut rng = Rng::new(3);
    for id in 0..400u64 {
        let m = (id % 2) as usize;
        let sample = rng.usize_below(entries[m].test.len());
        assert!(queues[m].push(Frame {
            id,
            sample,
            enqueued: Instant::now(),
        }));
    }
    let stop = AtomicBool::new(true);
    let cfg = DrainConfig {
        workers: 2,
        batch: 16,
        max_wait: Duration::from_millis(1),
        slo_ms: 1e9,
        collect_responses: true,
    };
    let err = batcher::drain(&queues, entries, &evals, &cfg, &stop)
        .expect_err("the flaky model's first failure must surface after the join");
    assert!(
        format!("{err:#}").contains("injected batch failure"),
        "surfaced error carries the evaluator's cause: {err:#}"
    );

    for q in &queues {
        assert!(q.is_empty(), "drain keeps going past failed batches");
    }
    let flaky_st = &queues[0].stats;
    let answered = flaky_st.answered.load(Ordering::Relaxed);
    let errors = flaky_st.errors.load(Ordering::Relaxed);
    assert!(errors > 0, "some batches failed");
    assert!(answered > 0, "the worker kept draining after a failure");
    assert_eq!(
        answered + errors,
        200,
        "exactly-once: every submitted frame is answered or errored"
    );
    assert_eq!(
        flaky_st.responses.lock().unwrap().len(),
        answered,
        "responses land only for answered frames"
    );
    let healthy_st = &queues[1].stats;
    assert_eq!(healthy_st.errors.load(Ordering::Relaxed), 0);
    assert_eq!(
        healthy_st.answered.load(Ordering::Relaxed),
        200,
        "sibling model fully served despite the failures"
    );
}

#[test]
fn fanin_feeds_every_model_equally() {
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = server::ServeConfig {
        datasets: vec!["a".into(), "b".into(), "c".into()],
        scenario: Scenario::FanIn,
        rate_hz: 300.0,
        duration: Duration::from_millis(250),
        sensors: 2,
        workers: 2,
        queue_cap: 4096,
        backend: Backend::Native,
        synthetic: true,
        ..server::ServeConfig::default()
    };
    let rep = server::run(&store, &cfg).unwrap();
    assert_eq!(rep.models.len(), 3);
    let first = rep.models[0].requests;
    assert!(first > 0, "fan-in generates traffic");
    for m in &rep.models {
        assert_eq!(
            m.requests, first,
            "fan-in submits one frame per model per window"
        );
        assert_eq!(m.shed, 0);
        assert_eq!(m.requests, m.answered);
        assert_eq!(m.accuracy, 1.0);
    }
}
