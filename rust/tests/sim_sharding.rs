//! Differential tests for sharded gate-level simulation: the
//! multi-threaded block-sharded testbench paths must be bit-identical to
//! a deliberately naive single-threaded, single-sample reference loop —
//! on random models, including n < 64 and n not a multiple of 64 (partial
//! final 64-lane block).
//!
//! Artifact-free (random `QuantModel`s from the mini-propcheck kit), so
//! this suite runs in tier-1.

mod common;

use std::sync::Arc;

// Fixed-seed model builder for the non-property tests.
use common::rand_model as fixed_model;
use printed_mlp::circuits::{combinational, seq_multicycle, CombCircuit, SeqCircuit};
use printed_mlp::model::QuantModel;
use printed_mlp::netlist::Port;
use printed_mlp::sim::{testbench, Sim};
use printed_mlp::util::prng::Rng;
use printed_mlp::util::propcheck::{check, Gen};

// testutil is #[cfg(test)] inside the crate; rebuild a tiny generator here.
fn rand_model(g: &mut Gen, fmax: usize, hmax: usize, cmax: usize) -> QuantModel {
    let features = g.usize_in(2..=fmax).max(2);
    let hidden = g.usize_in(1..=hmax).max(1);
    let classes = g.usize_in(2..=cmax).max(2);
    let pmax = 6u32;
    let r = g.rng();
    let mut w1p = Vec::new();
    let mut w1s = Vec::new();
    for _ in 0..hidden * features {
        w1p.push(r.below(pmax as u64 + 1) as i32);
        w1s.push([-1, 0, 1][r.usize_below(3)]);
    }
    let mut w2p = Vec::new();
    let mut w2s = Vec::new();
    for _ in 0..classes * hidden {
        w2p.push(r.below(pmax as u64 + 1) as i32);
        w2s.push([-1, 0, 1][r.usize_below(3)]);
    }
    QuantModel {
        name: "shard".into(),
        features,
        classes,
        hidden,
        in_bits: 4,
        w_bits: 8,
        pmax,
        trunc: (r.below(6) + 1) as u32,
        seq_clock_ms: 100.0,
        comb_clock_ms: 320.0,
        float_acc: 0.0,
        train_acc: 0.0,
        test_acc: 0.0,
        w1p,
        w1s,
        b1: (0..hidden).map(|_| r.i32_range(-200, 200)).collect(),
        w2p,
        w2s,
        b2: (0..classes).map(|_| r.i32_range(-200, 200)).collect(),
    }
}


fn port<'a>(ports: &'a [Port], name: &str) -> &'a [u32] {
    &ports
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing port {name}"))
        .bits
}

/// Reference implementation: one sample at a time through its own
/// simulator pass, lane 0 only — deliberately the dumbest correct loop,
/// sharing no code with the sharded path beyond `Sim` itself.
fn ref_sequential(circ: &SeqCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    let net = &circ.netlist;
    let x = port(&net.inputs, "x").to_vec();
    let rst = port(&net.inputs, "rst")[0];
    let class_out = port(&net.outputs, "class_out").to_vec();
    let mut preds = Vec::with_capacity(n);
    for i in 0..n {
        let mut sim = Sim::new(net);
        sim.set(rst, !0u64);
        sim.set_word_all(&x, 0);
        sim.step();
        sim.set(rst, 0);
        for t in 0..circ.cycles {
            if t < circ.active.len() {
                let f = circ.active[t];
                sim.set_word_lanes(&x, &[xs[i * features + f] as i64]);
            } else {
                sim.set_word_all(&x, 0);
            }
            sim.step();
        }
        sim.settle();
        preds.push(sim.get_word_lane(&class_out, 0) as u16);
    }
    preds
}

/// Per-sample combinational reference (lane 0 only).
fn ref_combinational(circ: &CombCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    let net = &circ.netlist;
    let x_all = port(&net.inputs, "x_all").to_vec();
    let class_out = port(&net.outputs, "class_out").to_vec();
    let mut preds = Vec::with_capacity(n);
    for i in 0..n {
        let mut sim = Sim::new(net);
        for (slot, &f) in circ.active.iter().enumerate() {
            sim.set_word_lanes(&x_all[slot * 4..(slot + 1) * 4], &[xs[i * features + f] as i64]);
        }
        sim.eval();
        preds.push(sim.get_word_lane(&class_out, 0) as u16);
    }
    preds
}

fn mismatches(a: &[u16], b: &[u16]) -> usize {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

#[test]
fn sharded_sequential_matches_reference() {
    check("sharded seq == per-sample reference", 6, |g| {
        let m = rand_model(g, 8, 3, 3);
        let active: Vec<usize> = (0..m.features).collect();
        let circ = seq_multicycle::generate(&m, &active);
        // Deliberately awkward sizes: n < 64, one exact block, partial tail.
        let n = [7usize, 64, 70][g.usize_in(0..=2).min(2)];
        let xs: Vec<u8> = (0..n * m.features).map(|_| g.rng().below(16) as u8).collect();
        let want = ref_sequential(&circ, &xs, n, m.features);
        let serial = testbench::run_sequential_threads(&circ, &xs, n, m.features, 1);
        let sharded = testbench::run_sequential_threads(&circ, &xs, n, m.features, 4);
        mismatches(&want, &serial) == 0 && mismatches(&want, &sharded) == 0
    });
}

#[test]
fn sharded_combinational_matches_reference() {
    check("sharded comb == per-sample reference", 5, |g| {
        let m = rand_model(g, 7, 3, 3);
        let active: Vec<usize> = (0..m.features).collect();
        let circ = combinational::generate(&m, &active);
        let n = [5usize, 64, 66][g.usize_in(0..=2).min(2)];
        let xs: Vec<u8> = (0..n * m.features).map(|_| g.rng().below(16) as u8).collect();
        let want = ref_combinational(&circ, &xs, n, m.features);
        let serial = testbench::run_combinational_threads(&circ, &xs, n, m.features, 1);
        let sharded = testbench::run_combinational_threads(&circ, &xs, n, m.features, 3);
        mismatches(&want, &serial) == 0 && mismatches(&want, &sharded) == 0
    });
}

#[test]
fn partial_final_block_at_scale() {
    // n = 130 = two full 64-lane blocks + a 2-lane partial block, with
    // more workers than blocks; zero prediction mismatches required.
    let m = fixed_model(21, 10, 4, 4);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let n = 130;
    let mut r = Rng::new(77);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let want = ref_sequential(&circ, &xs, n, m.features);
    for threads in [1usize, 2, 3, 8] {
        let got = testbench::run_sequential_threads(&circ, &xs, n, m.features, threads);
        assert_eq!(
            mismatches(&want, &got),
            0,
            "threads={threads}: sharded run diverged from reference"
        );
    }
}

#[test]
fn width_sweep_matches_reference_at_scale() {
    // The naive per-sample reference vs every super-lane width × thread
    // count, sequential and combinational, with n chosen so every width
    // ends on a partial block.
    let m = fixed_model(29, 8, 3, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let seq = seq_multicycle::generate(&m, &active);
    let comb = combinational::generate(&m, &active);
    let n = 150;
    let mut r = Rng::new(41);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let want_seq = ref_sequential(&seq, &xs, n, m.features);
    let want_comb = ref_combinational(&comb, &xs, n, m.features);
    for w in [1usize, 2, 4, 8] {
        for threads in [1usize, 4] {
            let got = testbench::run_sequential_plan(
                &seq,
                &seq.sim_plan(),
                &xs,
                n,
                m.features,
                threads,
                w,
            );
            assert_eq!(want_seq, got, "seq w={w} threads={threads}");
            let got = testbench::run_combinational_plan(
                &comb,
                &comb.sim_plan(),
                &xs,
                n,
                m.features,
                threads,
                w,
            );
            assert_eq!(want_comb, got, "comb w={w} threads={threads}");
        }
    }
}

#[test]
fn tiny_n_below_one_block() {
    let m = fixed_model(22, 6, 2, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    for n in [1usize, 2, 63] {
        let mut r = Rng::new(n as u64);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let want = ref_sequential(&circ, &xs, n, m.features);
        let got = testbench::run_sequential_threads(&circ, &xs, n, m.features, 8);
        assert_eq!(want, got, "n={n}");
    }
}

#[test]
fn sim_plan_is_built_once_and_shared() {
    let m = fixed_model(23, 5, 2, 2);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let p1 = circ.sim_plan();
    let p2 = circ.sim_plan();
    assert!(Arc::ptr_eq(&p1, &p2), "plan must be cached on the circuit");
    assert_eq!(p1.n_cells(), circ.netlist.cells.len());
    assert_eq!(p1.n_nets(), circ.netlist.n_nets());
}
