//! Backend equivalence: the [`GateSimEvaluator`] (generated multi-cycle
//! circuit + sharded netlist simulation) must agree bit-exactly with the
//! [`NativeEvaluator`] (functional model) on random `QuantModel`s — under
//! full masks, random feature masks, and hybrid approximation masks.
//!
//! Unlike `runtime_roundtrip.rs`, this suite is artifact-free (no `make
//! artifacts` needed), so the three-backend agreement guarantee is
//! checked in tier-1 on every run.

mod common;

use common::rand_model;
use printed_mlp::model::{importance, ApproxTables};
use printed_mlp::runtime::{Backend, Evaluator, GateSimEvaluator, NativeEvaluator};
use printed_mlp::util::prng::Rng;

#[test]
fn gatesim_matches_native_exact() {
    for seed in [1u64, 2, 3] {
        let m = rand_model(seed, 9, 4, 3);
        let native = NativeEvaluator { model: &m };
        let gate = GateSimEvaluator::with_threads(&m, 4);
        let n = 100; // partial final 64-lane block
        let mut r = Rng::new(seed ^ 0xABCD);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let got = gate.predict(&xs, n, &fm, &am, &t).unwrap();
        let want = native.predict(&xs, n, &fm, &am, &t);
        assert_eq!(got, want, "seed {seed}: gatesim and native diverge");
    }
}

#[test]
fn gatesim_matches_native_under_masks_and_approx() {
    let m = rand_model(7, 10, 4, 3);
    let native = NativeEvaluator { model: &m };
    // One evaluator across trials: exercises the mask-keyed circuit cache
    // (rebuild on change, reuse on repeat).
    let gate = GateSimEvaluator::new(&m);
    let n = 80;
    let mut r = Rng::new(99);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    for trial in 0..3 {
        // Random feature mask (always keep feature 0 so the schedule is
        // nonempty) and random approximation mask with real tables.
        let fm: Vec<u8> = (0..m.features)
            .map(|f| if f == 0 || r.chance(0.8) { 1 } else { 0 })
            .collect();
        let am: Vec<u8> = (0..m.hidden).map(|_| if r.chance(0.5) { 1 } else { 0 }).collect();
        let tables = importance::approx_tables(&m, &xs, n, &fm);
        let got = gate.predict(&xs, n, &fm, &am, &tables).unwrap();
        let want = native.predict(&xs, n, &fm, &am, &tables);
        assert_eq!(got, want, "trial {trial}: divergence under masks/approx");

        // Repeat with identical masks: must hit the circuit cache and
        // still agree.
        let again = gate.predict(&xs, n, &fm, &am, &tables).unwrap();
        assert_eq!(again, want, "trial {trial}: cached circuit diverges");
    }
}

#[test]
fn trait_accuracy_agrees_across_backends() {
    let m = rand_model(13, 8, 3, 3);
    let native = NativeEvaluator { model: &m };
    let gate = GateSimEvaluator::with_threads(&m, 2);
    let n = 70;
    let mut r = Rng::new(5);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let ys: Vec<u16> = (0..n).map(|_| r.below(m.classes as u64) as u16).collect();
    let split = printed_mlp::data::Split {
        xs,
        ys,
        features: m.features,
    };
    let fm = vec![1u8; m.features];
    let am = vec![0u8; m.hidden];
    let t = ApproxTables::disabled(m.hidden);
    let a = Evaluator::accuracy(&native, &split, &fm, &am, &t).unwrap();
    let b = Evaluator::accuracy(&gate, &split, &fm, &am, &t).unwrap();
    assert_eq!(a, b, "accuracy must be identical, not just close");
}

#[test]
fn backend_resolution_is_concrete() {
    let (_engine, b) = Backend::Auto.resolve().unwrap();
    assert!(matches!(b, Backend::Native | Backend::Pjrt));
    // Explicit backends pass through untouched.
    assert_eq!(Backend::GateSim.resolve().unwrap().1, Backend::GateSim);
    assert_eq!(Backend::Native.resolve().unwrap().1, Backend::Native);
}
