//! Property-based tests (mini-propcheck; proptest is unavailable offline)
//! over the coordinator-side invariants: routing/масks, NSGA Pareto
//! properties, RFP frontier properties, netlist/simulator algebra, and
//! the circuit/functional-model equivalence on random models.

use printed_mlp::circuits::{combinational, rtl, seq_multicycle};
use printed_mlp::model::importance;
use printed_mlp::netlist::Netlist;
use printed_mlp::nsga::{self, Individual};
use printed_mlp::sim::{testbench, Sim};
use printed_mlp::util::propcheck::{check, Gen};

// testutil is #[cfg(test)] inside the crate; rebuild a tiny generator here.
fn rand_model(g: &mut Gen, fmax: usize, hmax: usize, cmax: usize) -> printed_mlp::model::QuantModel {
    let features = g.usize_in(2..=fmax).max(2);
    let hidden = g.usize_in(1..=hmax).max(1);
    let classes = g.usize_in(2..=cmax).max(2);
    let pmax = 6u32;
    let r = g.rng();
    let mut w1p = Vec::new();
    let mut w1s = Vec::new();
    for _ in 0..hidden * features {
        w1p.push(r.below(pmax as u64 + 1) as i32);
        w1s.push([-1, 0, 1][r.usize_below(3)]);
    }
    let mut w2p = Vec::new();
    let mut w2s = Vec::new();
    for _ in 0..classes * hidden {
        w2p.push(r.below(pmax as u64 + 1) as i32);
        w2s.push([-1, 0, 1][r.usize_below(3)]);
    }
    printed_mlp::model::QuantModel {
        name: "prop".into(),
        features,
        classes,
        hidden,
        in_bits: 4,
        w_bits: 8,
        pmax,
        trunc: (r.below(6) + 1) as u32,
        seq_clock_ms: 100.0,
        comb_clock_ms: 320.0,
        float_acc: 0.0,
        train_acc: 0.0,
        test_acc: 0.0,
        w1p,
        w1s,
        b1: (0..hidden).map(|_| r.i32_range(-200, 200)).collect(),
        w2p,
        w2s,
        b2: (0..classes).map(|_| r.i32_range(-200, 200)).collect(),
    }
}

#[test]
fn prop_multicycle_circuit_equals_model() {
    check("multicycle == functional model", 12, |g| {
        let m = rand_model(g, 10, 4, 4);
        let active: Vec<usize> = (0..m.features).collect();
        let circ = seq_multicycle::generate(&m, &active);
        let samples = 8;
        let xs: Vec<u8> = (0..samples * m.features)
            .map(|_| g.rng().below(16) as u8)
            .collect();
        let preds = testbench::run_sequential(&circ, &xs, samples, m.features);
        (0..samples).all(|i| {
            let x: Vec<i32> = (0..m.features).map(|f| xs[i * m.features + f] as i32).collect();
            preds[i] as usize == m.forward_exact(&x).0
        })
    });
}

#[test]
fn prop_combinational_circuit_equals_model() {
    check("combinational == functional model", 10, |g| {
        let m = rand_model(g, 9, 3, 3);
        let active: Vec<usize> = (0..m.features).collect();
        let circ = combinational::generate(&m, &active);
        let samples = 8;
        let xs: Vec<u8> = (0..samples * m.features)
            .map(|_| g.rng().below(16) as u8)
            .collect();
        let preds = testbench::run_combinational(&circ, &xs, samples, m.features);
        (0..samples).all(|i| {
            let x: Vec<i32> = (0..m.features).map(|f| xs[i * m.features + f] as i32).collect();
            preds[i] as usize == m.forward_exact(&x).0
        })
    });
}

#[test]
fn prop_hybrid_circuit_equals_model_under_masks() {
    check("hybrid == functional model under random approx masks", 10, |g| {
        let m = rand_model(g, 8, 4, 3);
        let active: Vec<usize> = (0..m.features).collect();
        let samples = 8;
        let xs: Vec<u8> = (0..samples * m.features)
            .map(|_| g.rng().below(16) as u8)
            .collect();
        let fm = vec![1u8; m.features];
        let tables = importance::approx_tables(&m, &xs, samples, &fm);
        let approx: Vec<bool> = (0..m.hidden).map(|_| g.bool()).collect();
        let circ = printed_mlp::circuits::hybrid::generate(&m, &active, &approx, &tables);
        let preds = testbench::run_sequential(&circ, &xs, samples, m.features);
        let am: Vec<u8> = approx.iter().map(|&b| b as u8).collect();
        (0..samples).all(|i| {
            let x: Vec<i32> = (0..m.features).map(|f| xs[i * m.features + f] as i32).collect();
            preds[i] as usize == m.forward(&x, &fm, &am, &tables).0
        })
    });
}

#[test]
fn prop_rtl_adder_is_binary_addition() {
    check("rtl add == i64 add (mod 2^w)", 60, |g| {
        let w = g.usize_in(2..=16).max(2);
        let a = g.i32_in(-(1 << (w - 1))..=(1 << (w - 1)) - 1) as i64;
        let b = g.i32_in(-(1 << (w - 1))..=(1 << (w - 1)) - 1) as i64;
        let mut n = Netlist::new("t");
        let aw = n.add_input("a", w);
        let bw = n.add_input("b", w);
        let y = rtl::add(&mut n, &aw, &bw);
        n.add_output("y", y.clone());
        let mut s = Sim::new(&n);
        s.set_word_all(&aw, a);
        s.set_word_all(&bw, b);
        s.eval();
        let mask = (1i64 << w) - 1;
        s.get_word_lane(&y, 0) as i64 == ((a + b) & mask)
    });
}

#[test]
fn prop_mux_tree_indexes() {
    check("mux tree == array index", 40, |g| {
        let nitems = g.usize_in(1..=20).max(1);
        let width = g.usize_in(1..=8).max(1);
        let items: Vec<i64> = (0..nitems)
            .map(|_| g.rng().below(1 << width) as i64)
            .collect();
        let sel = g.rng().usize_below(nitems);
        let selw = printed_mlp::circuits::index_bits(nitems);
        let mut n = Netlist::new("t");
        let sw = n.add_input("sel", selw);
        let words: Vec<_> = items.iter().map(|&v| n.const_word(v, width)).collect();
        let y = rtl::mux_tree(&mut n, &sw, &words);
        n.add_output("y", y.clone());
        let mut s = Sim::new(&n);
        s.set_word_all(&sw, sel as i64);
        s.eval();
        s.get_word_lane(&y, 0) as i64 == items[sel]
    });
}

#[test]
fn prop_nsga_front_nondominated_and_sorted() {
    check("NSGA front mutually non-dominated", 8, |g| {
        let len = g.usize_in(3..=10).max(3);
        let cfg = nsga::NsgaConfig {
            pop_size: 12,
            generations: 6,
            seed: g.rng().next_u64(),
            ..Default::default()
        };
        // Random linear objective weights per run.
        let w1: f64 = g.f64_unit();
        let front: Vec<Individual> = nsga::run(len, &cfg, |genome| {
            let ones = genome.iter().filter(|&&b| b).count() as f64;
            vec![ones * w1, (len as f64 - ones) * (1.0 - w1)]
        });
        front.iter().all(|a| {
            front
                .iter()
                .all(|b| a.genome == b.genome || !nsga::dominates(&b.objectives, &a.objectives))
        })
    });
}

#[test]
fn prop_qrelu_circuit_equals_function() {
    check("qReLU unit == software qrelu", 40, |g| {
        let w = g.usize_in(6..=20).max(6);
        let trunc = g.usize_in(0..=10);
        let v = g.i32_in(-(1 << (w - 1))..=(1 << (w - 1)) - 1);
        let mut n = Netlist::new("t");
        let acc = n.add_input("acc", w);
        let y = rtl::qrelu_unit(&mut n, &acc, trunc);
        n.add_output("y", y.clone());
        let mut s = Sim::new(&n);
        s.set_word_all(&acc, v as i64);
        s.eval();
        s.get_word_lane(&y, 0) as i32 == printed_mlp::model::qrelu(v, trunc as u32)
    });
}
