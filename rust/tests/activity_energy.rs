//! Differential tests for activity profiling (per-net toggle counters)
//! and the measured-energy layer on top of it:
//!
//! - toggle counts are bit-identical at every super-lane width
//!   `W ∈ {1,2,4,8}` and thread count — including partial tail blocks —
//!   within each plan form, and DFF commit activity agrees *across* plan
//!   forms (q nets are part of the external contract; internal comb nets
//!   legitimately differ under inversion fusing);
//! - the interpreted plan's counts match a naive per-net test-side
//!   oracle over `propcheck::rand_netlist` circuits (DFF state nets,
//!   masked partial-population lanes, mixed eval/step/reset schedules);
//! - counters never perturb simulation: activity runs predict
//!   bit-identically to the plain counters-off entry points;
//! - pricing measured activity through `tech::energy_report` is
//!   monotone: approximating more neurons never adds dynamic energy.
//!
//! Artifact-free, so this suite runs in tier-1.

mod common;

use std::sync::Arc;

use common::rand_model;
use printed_mlp::approx;
use printed_mlp::circuits::{combinational, hybrid, seq_multicycle};
use printed_mlp::netlist::{Cell, Netlist, CONST1};
use printed_mlp::sim::{testbench, Sim, SimPlan};
use printed_mlp::tech;
use printed_mlp::util::propcheck::{check, rand_netlist};
use printed_mlp::util::prng::Rng;

#[test]
fn counts_invariant_across_widths_threads_and_partial_tails() {
    let m = rand_model(13, 9, 5, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let interp = Arc::new(SimPlan::new(&circ.netlist));
    let comp = Arc::new(SimPlan::compiled(&circ.netlist));
    let n_max = 300; // 4 full 64-lane words + a 44-lane partial tail
    let mut r = Rng::new(4);
    let xs: Vec<u8> = (0..n_max * m.features).map(|_| r.below(16) as u8).collect();

    for n in [1usize, 65, 300] {
        let head = &xs[..n * m.features];
        let want = testbench::run_sequential_plan(&circ, &interp, head, n, m.features, 1, 1);
        for plan in [&interp, &comp] {
            let (_, base) = testbench::run_sequential_plan_activity(
                &circ, plan, head, n, m.features, 1, 1, None,
            );
            assert!(base.total_toggles() > 0, "n={n}: a live run must toggle");
            let base_rows: Vec<u64> = plan.gate_activity(&base).iter().map(|g| g.toggles).collect();
            for w in [1usize, 2, 4, 8] {
                for threads in [1usize, 3] {
                    let (preds, act) = testbench::run_sequential_plan_activity(
                        &circ, plan, head, n, m.features, threads, w, None,
                    );
                    assert_eq!(
                        preds,
                        want,
                        "predictions drifted: n={n} w={w} threads={threads} compiled={}",
                        plan.is_compiled()
                    );
                    let rows: Vec<u64> =
                        plan.gate_activity(&act).iter().map(|g| g.toggles).collect();
                    assert_eq!(
                        rows,
                        base_rows,
                        "counts drifted: n={n} w={w} threads={threads} compiled={}",
                        plan.is_compiled()
                    );
                }
            }
        }
    }

    // DFF commit activity agrees across plan forms: q trajectories are
    // externally observable, so their masked transition counts must
    // match gate for gate (sorted — row order is plan-internal).
    let (_, ai) =
        testbench::run_sequential_plan_activity(&circ, &interp, &xs, n_max, m.features, 1, 1, None);
    let (_, ac) =
        testbench::run_sequential_plan_activity(&circ, &comp, &xs, n_max, m.features, 1, 1, None);
    let dffs = |plan: &Arc<SimPlan>, act: &printed_mlp::sim::Activity| {
        let mut t: Vec<u64> = plan
            .gate_activity(act)
            .iter()
            .filter(|g| g.kind == "DFF")
            .map(|g| g.toggles)
            .collect();
        t.sort_unstable();
        t
    };
    let (di, dc) = (dffs(&interp, &ai), dffs(&comp, &ac));
    assert!(!di.is_empty(), "sequential circuit must report DFF activity");
    assert_eq!(di, dc, "DFF commit counts must agree across plan forms");
}

/// Naive per-sample reference: one `u64` value and one toggle counter
/// per source net, evaluated straight off the netlist in topo order.
/// Mirrors the simulator's contract — count at every producing store
/// (masked), count register commits two-phase, never count the direct
/// register fill of a reset.
struct Oracle {
    vals: Vec<u64>,
    counts: Vec<u64>,
    mask: u64,
}

impl Oracle {
    fn new(n: &Netlist, lanes: usize) -> Oracle {
        let mut vals = vec![0u64; n.n_nets()];
        vals[CONST1 as usize] = !0u64;
        let mask = if lanes >= 64 { !0u64 } else { (1u64 << lanes) - 1 };
        Oracle { vals, counts: vec![0; n.n_nets()], mask }
    }

    fn eval(&mut self, n: &Netlist, order: &[usize]) {
        for &ci in order {
            let v = &self.vals;
            let (y, new) = match n.cells[ci] {
                Cell::Inv { a, y } => (y, !v[a as usize]),
                Cell::Buf { a, y } => (y, v[a as usize]),
                Cell::Nand2 { a, b, y } => (y, !(v[a as usize] & v[b as usize])),
                Cell::Nor2 { a, b, y } => (y, !(v[a as usize] | v[b as usize])),
                Cell::And2 { a, b, y } => (y, v[a as usize] & v[b as usize]),
                Cell::Or2 { a, b, y } => (y, v[a as usize] | v[b as usize]),
                Cell::Xor2 { a, b, y } => (y, v[a as usize] ^ v[b as usize]),
                Cell::Xnor2 { a, b, y } => (y, !(v[a as usize] ^ v[b as usize])),
                Cell::Mux2 { a, b, sel, y } => {
                    let s = v[sel as usize];
                    (y, (v[a as usize] & !s) | (v[b as usize] & s))
                }
                Cell::Dff { .. } => unreachable!("comb order contains a DFF"),
            };
            self.counts[y as usize] +=
                ((new ^ self.vals[y as usize]) & self.mask).count_ones() as u64;
            self.vals[y as usize] = new;
        }
    }

    fn step(&mut self, n: &Netlist, order: &[usize]) {
        self.eval(n, order);
        // Two-phase commit: capture every next-q from pre-commit values
        // (a register may feed another register's data), then count the
        // transition and overwrite.
        let mut next = Vec::new();
        for c in &n.cells {
            if let Cell::Dff { d, q, en, rst, rstval } = *c {
                let rv = if rstval { !0u64 } else { 0u64 };
                let v = &self.vals;
                let held = (v[en as usize] & v[d as usize]) | (!v[en as usize] & v[q as usize]);
                next.push((q, (v[rst as usize] & rv) | (!v[rst as usize] & held)));
            }
        }
        for (q, nq) in next {
            self.counts[q as usize] +=
                ((nq ^ self.vals[q as usize]) & self.mask).count_ones() as u64;
            self.vals[q as usize] = nq;
        }
    }

    fn reset(&mut self, n: &Netlist, order: &[usize]) {
        // Registers jump straight to their reset value, uncounted (a
        // forced reset is not switching activity); the propagation that
        // follows is counted like any other eval.
        for c in &n.cells {
            if let Cell::Dff { q, rstval, .. } = *c {
                self.vals[q as usize] = if rstval { !0u64 } else { 0u64 };
            }
        }
        self.eval(n, order);
    }
}

#[test]
fn interpreted_counts_match_naive_oracle_on_random_netlists() {
    check("interpreted toggle counts == naive per-net oracle", 30, |g| {
        let n = rand_netlist(g);
        let order = n.topo_order();
        // Partial populations exercise the lane mask: garbage above
        // `lanes` must propagate but never count.
        let lanes = g.usize_in(1..=64);
        let plan = Arc::new(SimPlan::new(&n));
        let mut sim = Sim::from_plan(plan.clone());
        let mut off = Sim::from_plan(plan.clone());
        sim.set_activity(true);
        sim.activity_begin_block(lanes);
        let mut oracle = Oracle::new(&n, lanes);
        let mut r = Rng::new(g.rng().next_u64());
        let mut ok = true;
        for _cycle in 0..10 {
            for port in &n.inputs {
                for &bit in &port.bits {
                    let v = r.next_u64();
                    sim.set(bit, v);
                    off.set(bit, v);
                    oracle.vals[bit as usize] = v;
                }
            }
            match r.below(8) {
                0 => {
                    sim.reset();
                    off.reset();
                    oracle.reset(&n, &order);
                }
                1 => {
                    sim.eval();
                    off.eval();
                    oracle.eval(&n, &order);
                }
                _ => {
                    sim.step();
                    off.step();
                    oracle.step(&n, &order);
                }
            }
            // Counting must never perturb the simulation itself.
            for port in &n.outputs {
                for &bit in &port.bits {
                    ok = ok && sim.get(bit) == off.get(bit);
                }
            }
        }
        let act = sim.take_activity();
        ok = ok && act.total_toggles() == oracle.counts.iter().sum::<u64>();
        // Per-gate rows: comb cells in topo order, then DFFs in cell
        // order — exactly how `gate_activity` resolves an interpreted
        // plan's counters.
        let mut want: Vec<u64> = order
            .iter()
            .map(|&ci| oracle.counts[n.cells[ci].output() as usize])
            .collect();
        for c in &n.cells {
            if c.is_seq() {
                want.push(oracle.counts[c.output() as usize]);
            }
        }
        let got: Vec<u64> = plan.gate_activity(&act).iter().map(|g| g.toggles).collect();
        ok && got == want
    });
}

#[test]
fn activity_runs_predict_identically_to_plain_runs() {
    let m = rand_model(23, 8, 4, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let n = 130usize;
    let mut r = Rng::new(6);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();

    let seq = seq_multicycle::generate(&m, &active);
    let plan = Arc::new(SimPlan::compiled(&seq.netlist));
    let want = testbench::run_sequential_plan(&seq, &plan, &xs, n, m.features, 2, 2);
    let (got, act) =
        testbench::run_sequential_plan_activity(&seq, &plan, &xs, n, m.features, 2, 2, None);
    assert_eq!(got, want, "sequential: counters changed predictions");
    assert!(!act.is_empty() && act.total_toggles() > 0);

    let comb = combinational::generate(&m, &active);
    let plan = Arc::new(SimPlan::compiled(&comb.netlist));
    let want = testbench::run_combinational_plan(&comb, &plan, &xs, n, m.features, 2, 2);
    let (got, act) =
        testbench::run_combinational_plan_activity(&comb, &plan, &xs, n, m.features, 2, 2, None);
    assert_eq!(got, want, "combinational: counters changed predictions");
    assert!(act.total_toggles() > 0);
    // Combinational counts carry the same width/thread invariance.
    let rows = |a: &printed_mlp::sim::Activity| -> Vec<u64> {
        plan.gate_activity(a).iter().map(|g| g.toggles).collect()
    };
    let base = rows(&act);
    let (_, wide) =
        testbench::run_combinational_plan_activity(&comb, &plan, &xs, n, m.features, 3, 8, None);
    assert_eq!(rows(&wide), base, "combinational counts drifted across W/threads");
}

#[test]
fn dynamic_energy_never_grows_with_more_approximated_neurons() {
    // Nested approximation masks over one model: every approximated
    // neuron swaps its multi-cycle MAC hardware for a single-cycle
    // table lookup, so measured switching energy must not increase.
    let m = rand_model(19, 24, 6, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let n = 128usize;
    let mut r = Rng::new(8);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &xs, n, &fm);

    let masks: [Vec<bool>; 3] = [
        vec![false; m.hidden],
        (0..m.hidden).map(|i| i < m.hidden / 2).collect(),
        vec![true; m.hidden],
    ];
    let mut last = f64::INFINITY;
    for approx in masks {
        let circ = hybrid::generate(&m, &active, &approx, &tables);
        let plan = circ.sim_plan();
        let (_, act) =
            testbench::run_sequential_plan_activity(&circ, &plan, &xs, n, m.features, 1, 0, None);
        let rep = tech::report(&circ.netlist);
        let er = tech::energy_report(
            &rep,
            &plan.gate_activity(&act),
            circ.cycles + 1,
            m.seq_clock_ms,
            n as u64,
        );
        assert!(er.dynamic_mj > 0.0, "a live run must price some switching");
        assert!(
            er.dynamic_mj <= last,
            "approximating more neurons added dynamic energy: {} > {last}",
            er.dynamic_mj
        );
        last = er.dynamic_mj;
    }
}
