//! Gate-level circuits vs the bit-exact functional model on *real trained
//! models and data* — the hardware half of the validation triangle
//! (PJRT artifact ↔ functional model ↔ netlist simulation).
//!
//! Requires `make artifacts` (skips politely otherwise).

use printed_mlp::circuits::{combinational, hybrid, seq_multicycle, seq_sota};
use printed_mlp::data::ArtifactStore;
use printed_mlp::model::{importance, ApproxTables};
use printed_mlp::sim::testbench;

fn store() -> Option<ArtifactStore> {
    let s = ArtifactStore::discover();
    if s.has("spectf") {
        Some(s)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn expect_preds(
    m: &printed_mlp::model::QuantModel,
    xs: &[u8],
    n: usize,
    fm: &[u8],
    am: &[u8],
    t: &ApproxTables,
) -> Vec<u16> {
    (0..n)
        .map(|i| {
            let x: Vec<i32> = (0..m.features)
                .map(|f| xs[i * m.features + f] as i32)
                .collect();
            m.forward(&x, fm, am, t).0 as u16
        })
        .collect()
}

#[test]
fn multicycle_matches_model_on_spectf() {
    let Some(store) = store() else { return };
    let m = store.model("spectf").unwrap();
    let ds = store.dataset("spectf").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let split = ds.test.head(128);
    let got = testbench::run_sequential(&circ, &split.xs, split.len(), m.features);
    let fm = vec![1u8; m.features];
    let am = vec![0u8; m.hidden];
    let want = expect_preds(&m, &split.xs, split.len(), &fm, &am, &ApproxTables::disabled(m.hidden));
    assert_eq!(got, want);
}

#[test]
fn seq_sota_matches_model_on_spectf() {
    let Some(store) = store() else { return };
    let m = store.model("spectf").unwrap();
    let ds = store.dataset("spectf").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_sota::generate(&m, &active);
    let split = ds.test.head(128);
    let got = testbench::run_sequential(&circ, &split.xs, split.len(), m.features);
    let fm = vec![1u8; m.features];
    let am = vec![0u8; m.hidden];
    let want = expect_preds(&m, &split.xs, split.len(), &fm, &am, &ApproxTables::disabled(m.hidden));
    assert_eq!(got, want);
}

#[test]
fn combinational_matches_model_on_gas() {
    let Some(store) = store() else { return };
    let m = store.model("gas").unwrap();
    let ds = store.dataset("gas").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    let circ = combinational::generate(&m, &active);
    let split = ds.test.head(128);
    let got = testbench::run_combinational(&circ, &split.xs, split.len(), m.features);
    let fm = vec![1u8; m.features];
    let am = vec![0u8; m.hidden];
    let want = expect_preds(&m, &split.xs, split.len(), &fm, &am, &ApproxTables::disabled(m.hidden));
    assert_eq!(got, want);
}

#[test]
fn hybrid_matches_model_on_spectf() {
    let Some(store) = store() else { return };
    let m = store.model("spectf").unwrap();
    let ds = store.dataset("spectf").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    let fm = vec![1u8; m.features];
    let tables = importance::approx_tables(&m, &ds.train.xs, ds.train.len(), &fm);
    let approx: Vec<bool> = (0..m.hidden).map(|h| h % 2 == 0).collect();
    let circ = hybrid::generate(&m, &active, &approx, &tables);
    let split = ds.test.head(128);
    let got = testbench::run_sequential(&circ, &split.xs, split.len(), m.features);
    let am: Vec<u8> = approx.iter().map(|&b| b as u8).collect();
    let want = expect_preds(&m, &split.xs, split.len(), &fm, &am, &tables);
    assert_eq!(got, want);
}

#[test]
fn architectures_rank_as_paper_claims() {
    // Structural sanity on a real model: seq-sota is register-dominated
    // and larger than ours; ours is much smaller than seq-sota.
    let Some(store) = store() else { return };
    let m = store.model("arrhythmia").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    let ours = printed_mlp::tech::report(&seq_multicycle::generate(&m, &active).netlist);
    let sota = printed_mlp::tech::report(&seq_sota::generate(&m, &active).netlist);
    assert!(sota.area_cm2 > 3.0 * ours.area_cm2, "sota {} ours {}", sota.area_cm2, ours.area_cm2);
    assert!(sota.power_mw > 3.0 * ours.power_mw);
}

#[test]
fn verilog_emission_golden_shape() {
    let Some(store) = store() else { return };
    let m = store.model("spectf").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let v = printed_mlp::netlist::verilog::emit(&circ.netlist);
    assert!(v.contains("module spectf_seq_multicycle (clk, x, rst, class_out);"));
    assert!(v.contains("DFF_ER"));
    assert!(v.contains("endmodule"));
    // Every emitted instance count matches the IR.
    let inst_count = v.matches("\n  INV u").count()
        + v.matches("\n  BUF u").count()
        + v.matches("\n  NAND2 u").count()
        + v.matches("\n  NOR2 u").count()
        + v.matches("\n  AND2 u").count()
        + v.matches("\n  OR2 u").count()
        + v.matches("\n  XOR2 u").count()
        + v.matches("\n  XNOR2 u").count()
        + v.matches("\n  MUX2 u").count()
        + v.matches("\n  DFF_ER u").count();
    assert_eq!(inst_count, circ.netlist.cells.len());
}
