//! Fault-injection differentials (DESIGN.md §Faults):
//!
//! - a zero-fault campaign cell is bit-identical to a plain serve run
//!   over the same trace (same request counts, same accuracy — the
//!   clean path is untouched by the fault machinery);
//! - the trace scenario is replayable: two serve runs over the same
//!   synthesized trace submit identical per-model request streams;
//! - fault-injected predictions are bit-identical across super-lane
//!   widths `W ∈ {1,2,4,8}` and thread counts for the same fault list,
//!   on both the sequential and combinational circuits;
//! - faults on externally-written nets (inputs, register state) agree
//!   between the interpreted oracle and the compiled plan — `Comb`-net
//!   faults are excluded because inversion fusing legitimately gives
//!   the two plan forms different internal wire values;
//! - a stuck-at fault forces the named net's value on random
//!   (mini-propcheck) netlists, on both plan forms;
//! - activity profiling (per-net toggle counters) composes with fault
//!   injection: faulted predictions are bit-identical with counters on
//!   or off, and the counts themselves are deterministic — counters
//!   observe each producing store *before* the scheduled fault mask is
//!   applied (see sim/fault.rs), so the ordering is pinned by test.

use std::sync::Arc;
use std::time::Duration;

use printed_mlp::circuits::{combinational, rtl, seq_multicycle};
use printed_mlp::data::ArtifactStore;
use printed_mlp::model::synth;
use printed_mlp::netlist::{Netlist, NetRole};
use printed_mlp::runtime::Backend;
use printed_mlp::server::{self, ArchKind, CampaignConfig, Scenario, ServeConfig};
use printed_mlp::sim::fault::{default_roles, Fault, FaultKind, FaultList};
use printed_mlp::sim::{testbench, Sim, SimPlan};
use printed_mlp::util::propcheck::check;
use printed_mlp::util::prng::Rng;

fn trace_cfg() -> ServeConfig {
    ServeConfig {
        datasets: vec!["f0".into(), "f1".into()],
        scenario: Scenario::Trace,
        rate_hz: 300.0,
        duration: Duration::from_millis(150),
        sensors: 2,
        workers: 2,
        batch: 32,
        queue_cap: 8192,
        slo_ms: 1e9,
        seed: 13,
        backend: Backend::GateSim,
        sim_lanes: 2,
        synthetic: true,
        ..ServeConfig::default()
    }
}

#[test]
fn zero_fault_campaign_is_bit_identical_to_plain_serve() {
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = trace_cfg();
    let plain = server::run(&store, &cfg).unwrap();
    assert!(plain.total_requests() > 0, "trace generates traffic");

    let camp = CampaignConfig {
        serve: cfg,
        archs: vec![ArchKind::Ours],
        levels: vec![(0, 0)],
        ..CampaignConfig::default()
    };
    let rep = server::campaign::run_campaign(&store, &camp).unwrap();
    assert_eq!(rep.scenario, Scenario::Trace);
    assert_eq!(rep.rows.len(), plain.models.len(), "one row per model");

    for (row, m) in rep.rows.iter().zip(&plain.models) {
        assert_eq!(row.model, m.name);
        assert_eq!((row.stuck, row.transient), (0, 0));
        assert_eq!(
            row.degradation, 0.0,
            "{}: zero faults must not move the deterministic accuracy",
            row.model
        );
        assert_eq!(row.baseline_accuracy, row.fault_accuracy);
        assert_eq!(row.baseline_accuracy, 1.0, "self-labeled synthetic split");
        // Same trace, same evaluators ⇒ same request stream, bit-exact
        // predictions, nothing shed or errored on either path.
        assert_eq!(
            row.serve.requests, m.requests,
            "{}: replayed trace submits the same frames",
            row.model
        );
        assert_eq!(row.serve.answered, m.answered);
        assert_eq!(row.serve.requests, row.serve.answered);
        assert_eq!(row.serve.shed, 0);
        assert_eq!(row.serve.errors, 0);
        assert_eq!(m.shed, 0);
        assert_eq!(m.errors, 0);
        if row.serve.answered > 0 {
            assert_eq!(row.serve.accuracy, 1.0);
            assert_eq!(m.accuracy, 1.0);
        }
    }
}

#[test]
fn trace_serve_requests_are_replayable() {
    let store = ArtifactStore::new("/nonexistent-artifacts-root");
    let cfg = trace_cfg();
    let a = server::run(&store, &cfg).unwrap();
    let b = server::run(&store, &cfg).unwrap();
    assert!(a.total_requests() > 0);
    for (ma, mb) in a.models.iter().zip(&b.models) {
        assert_eq!(ma.name, mb.name);
        assert_eq!(
            ma.requests, mb.requests,
            "{}: the replayed trace offers identical load",
            ma.name
        );
        assert_eq!(ma.answered, mb.answered);
        assert_eq!(ma.accuracy, mb.accuracy);
    }
}

#[test]
fn sequential_faults_bit_identical_across_widths_and_threads() {
    let m = synth::rand_model(41, 9, 6, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let plan = circ.sim_plan();
    let fl = FaultList::sample(&plan, &circ.netlist, &default_roles(), 8, 6, 0.2, 11);
    assert!(fl.stuck_count() > 0 && fl.transient_count() > 0);

    let n = 300; // not a block multiple: exercises the partial tail
    let mut r = Rng::new(77);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let reference =
        testbench::run_sequential_plan_faulted(&circ, &plan, &xs, n, m.features, 1, 1, Some(&fl));
    for w in [1usize, 2, 4, 8] {
        for threads in [1usize, 3] {
            let got = testbench::run_sequential_plan_faulted(
                &circ,
                &plan,
                &xs,
                n,
                m.features,
                threads,
                w,
                Some(&fl),
            );
            assert_eq!(
                reference, got,
                "sequential faulted run diverged at W={w}, threads={threads}"
            );
        }
    }
}

#[test]
fn combinational_faults_bit_identical_across_widths_and_threads() {
    let m = synth::rand_model(43, 8, 4, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = combinational::generate(&m, &active);
    let plan = circ.sim_plan();
    let fl = FaultList::sample(&plan, &circ.netlist, &default_roles(), 6, 4, 0.1, 17);
    assert!(!fl.is_empty());

    let n = 200;
    let mut r = Rng::new(78);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let reference =
        testbench::run_combinational_plan_faulted(&circ, &plan, &xs, n, m.features, 1, 1, Some(&fl));
    for w in [1usize, 2, 4, 8] {
        for threads in [1usize, 3] {
            let got = testbench::run_combinational_plan_faulted(
                &circ,
                &plan,
                &xs,
                n,
                m.features,
                threads,
                w,
                Some(&fl),
            );
            assert_eq!(
                reference, got,
                "combinational faulted run diverged at W={w}, threads={threads}"
            );
        }
    }
}

#[test]
fn source_faults_agree_between_interpreted_and_compiled_plans() {
    // Input/State nets exist verbatim in both plan forms; Comb nets are
    // excluded — inversion fusing means the compiled plan's internal
    // wires legitimately carry different (complemented) values.
    let m = synth::rand_model(45, 8, 5, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let compiled = circ.sim_plan();
    let interp = Arc::new(SimPlan::new(&circ.netlist));
    let roles = [NetRole::Input, NetRole::State];
    let fl = FaultList::sample(&compiled, &circ.netlist, &roles, 6, 2, 0.1, 21);
    assert!(!fl.is_empty());

    let n = 150;
    let mut r = Rng::new(79);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let via_compiled =
        testbench::run_sequential_plan_faulted(&circ, &compiled, &xs, n, m.features, 1, 2, Some(&fl));
    let via_interp =
        testbench::run_sequential_plan_faulted(&circ, &interp, &xs, n, m.features, 1, 2, Some(&fl));
    assert_eq!(
        via_compiled, via_interp,
        "interpreted oracle and compiled plan disagree under source faults"
    );
}

#[test]
fn prop_stuck_at_forces_value_on_random_netlists() {
    check("stuck-at forces the named net on both plan forms", 24, |g| {
        let w = g.usize_in(2..=10).max(2);
        let a = g.i32_in(-(1 << (w - 1))..=(1 << (w - 1)) - 1) as i64;
        let b = g.i32_in(-(1 << (w - 1))..=(1 << (w - 1)) - 1) as i64;
        let mut n = Netlist::new("t");
        let aw = n.add_input("a", w);
        let bw = n.add_input("b", w);
        let y = rtl::add(&mut n, &aw, &bw);
        n.add_output("y", y.clone());
        let bit = g.usize_in(0..=w - 1);
        let kind = if g.bool() {
            FaultKind::StuckAt1
        } else {
            FaultKind::StuckAt0
        };
        let list = FaultList {
            faults: vec![Fault { net: y[bit], kind }],
            seed: 0,
            flip_rate: 0.0,
        };
        let want = if kind == FaultKind::StuckAt1 { !0u64 } else { 0u64 };
        [
            Arc::new(SimPlan::new(&n)),
            Arc::new(SimPlan::compiled(&n)),
        ]
        .into_iter()
        .all(|plan| {
            if !plan.faultable(y[bit]) {
                return true; // folded away: no slot of its own to force
            }
            let mut s = Sim::from_plan(plan);
            s.set_faults(&list);
            s.set_word_all(&aw, a);
            s.set_word_all(&bw, b);
            s.eval();
            s.get(y[bit]) == want
        })
    });
}

#[test]
fn activity_profiling_composes_with_fault_injection() {
    // Toggle counters observe each producing store before the scheduled
    // fault mask lands on it (sim/fault.rs), and the fault machinery
    // never reads the counters — so turning profiling on under faults
    // must not move a single prediction, at any width or thread count,
    // and the counts themselves must be run-to-run deterministic.
    let m = synth::rand_model(47, 9, 5, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let plan = circ.sim_plan();
    let fl = FaultList::sample(&plan, &circ.netlist, &default_roles(), 7, 5, 0.2, 23);
    assert!(fl.stuck_count() > 0 && fl.transient_count() > 0);

    let n = 300; // partial tail block under every width
    let mut r = Rng::new(81);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let (ref_preds, ref_act) =
        testbench::run_sequential_plan_activity(&circ, &plan, &xs, n, m.features, 1, 1, Some(&fl));
    assert!(ref_act.total_toggles() > 0, "faulted run still toggles nets");
    for w in [1usize, 2, 4, 8] {
        for threads in [1usize, 3] {
            let off = testbench::run_sequential_plan_faulted(
                &circ,
                &plan,
                &xs,
                n,
                m.features,
                threads,
                w,
                Some(&fl),
            );
            let (on, act) = testbench::run_sequential_plan_activity(
                &circ,
                &plan,
                &xs,
                n,
                m.features,
                threads,
                w,
                Some(&fl),
            );
            assert_eq!(
                off, on,
                "counters changed faulted predictions at W={w}, threads={threads}"
            );
            assert_eq!(off, ref_preds, "faulted run diverged at W={w}, threads={threads}");
            let (a, b): (Vec<u64>, Vec<u64>) = (
                plan.gate_activity(&ref_act).iter().map(|g| g.toggles).collect(),
                plan.gate_activity(&act).iter().map(|g| g.toggles).collect(),
            );
            assert_eq!(a, b, "faulted toggle counts diverged at W={w}, threads={threads}");
        }
    }
}
