//! Differential + invariant suite for the parallel, memoized NSGA-II
//! approximation search.
//!
//! The contract under test (DESIGN.md §Perf): `nsga::run_batched` over
//! `approx::ParallelFitness` — per-generation offspring batches fanned
//! across worker threads sharing one read-only delta-logit fitness
//! cache (`model::cache`), plus a genome→objectives memo cache —
//! returns a **bit-identical** final Pareto front to the serial
//! reference `nsga::run` at the same seed, for every thread count and
//! with either cache on or off (`tests/fitness_cache.rs` covers the
//! delta-logit cache's own differentials).
//!
//! Also covers the NSGA-II structural invariants: non-dominated-sort
//! rank correctness on hand-built and random fronts, crowding-distance
//! boundary handling, and seed determinism — including 3-objective
//! tuples, the shape `--energy-objective` produces (`approx::
//! explore_energy` appends negated measured energy as objectives[2]).
//!
//! Artifact-free (random `QuantModel`s), so this suite runs in tier-1.

mod common;

use common::rand_model;
use printed_mlp::approx;
use printed_mlp::data::Split;
use printed_mlp::model::QuantModel;
use printed_mlp::nsga::{
    self, crowding_distance, dominates, non_dominated_sort, Individual, NsgaConfig, SerialFitness,
};
use printed_mlp::util::prng::Rng;
use printed_mlp::util::propcheck::{check, Gen};

/// Random 4-bit training split for `model`, fully determined by `seed`.
fn rand_split(seed: u64, model: &QuantModel, n: usize) -> Split {
    let mut r = Rng::new(seed);
    Split {
        xs: (0..n * model.features).map(|_| r.below(16) as u8).collect(),
        ys: (0..n).map(|_| r.below(model.classes as u64) as u16).collect(),
        features: model.features,
    }
}

fn assert_fronts_identical(a: &[Individual], b: &[Individual], what: &str) {
    assert_eq!(a.len(), b.len(), "front size differs: {what}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.genome, y.genome, "genome differs: {what}");
        assert_eq!(x.objectives, y.objectives, "objectives differ: {what}");
    }
}

fn mk(objectives: Vec<f64>) -> Individual {
    Individual {
        genome: vec![],
        objectives,
        rank: 0,
        crowding: 0.0,
    }
}

// ---------------------------------------------------------------------------
// Differential: serial reference vs parallel + memoized batch path
// ---------------------------------------------------------------------------

#[test]
fn parallel_memoized_front_bit_identical_to_serial() {
    let m = rand_model(33, 16, 8, 4);
    let split = rand_split(7, &m, 96);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
    let cfg = NsgaConfig {
        pop_size: 16,
        generations: 10,
        ..Default::default()
    };
    // The serial reference path, exactly as the coordinator's PJRT arm
    // drives it: one fitness closure call per genome through nsga::run.
    let serial = approx::explore(m.hidden, &cfg, |mask| {
        m.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
    });
    for threads in [1usize, 2, 4, 8] {
        let (parallel, stats) = approx::explore_parallel(&m, &split, &fm, &tables, &cfg, threads);
        assert_fronts_identical(&serial, &parallel, &format!("{threads} threads, cache on"));
        assert_eq!(stats.evals + stats.cache_hits, stats.requested);
        assert_eq!(stats.requested, cfg.pop_size * (cfg.generations + 1));
    }
}

#[test]
fn cache_off_is_still_bit_identical() {
    let m = rand_model(34, 12, 6, 3);
    let split = rand_split(11, &m, 64);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
    let base = NsgaConfig {
        pop_size: 12,
        generations: 8,
        ..Default::default()
    };
    let serial = approx::explore(m.hidden, &base, |mask| {
        m.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
    });
    let uncached = NsgaConfig {
        memoize: false,
        ..base.clone()
    };
    for threads in [1usize, 4] {
        let (parallel, stats) =
            approx::explore_parallel(&m, &split, &fm, &tables, &uncached, threads);
        assert_fronts_identical(&serial, &parallel, &format!("{threads} threads, cache off"));
        assert_eq!(stats.cache_hits, 0, "disabled cache must record no hits");
        assert_eq!(stats.evals, stats.requested);
    }
}

#[test]
fn scalar_and_cached_fitness_fronts_bit_identical() {
    // `nsga.cached_fitness` only changes how each accuracy is computed
    // (delta-logit cache vs full scalar forward), never its value:
    // serial oracle, scalar-parallel, and cached-parallel fronts must
    // coincide at every thread count.
    let m = rand_model(38, 14, 7, 4);
    let split = rand_split(19, &m, 80);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
    let cached = NsgaConfig {
        pop_size: 12,
        generations: 8,
        ..Default::default()
    };
    let scalar = NsgaConfig {
        cached_fitness: false,
        ..cached.clone()
    };
    let serial = approx::explore(m.hidden, &cached, |mask| {
        m.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
    });
    for threads in [1usize, 3] {
        let (with_cache, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cached, threads);
        let (without, _) = approx::explore_parallel(&m, &split, &fm, &tables, &scalar, threads);
        assert_fronts_identical(&serial, &with_cache, &format!("cached, {threads} threads"));
        assert_fronts_identical(&serial, &without, &format!("scalar, {threads} threads"));
    }
}

#[test]
fn memo_only_skips_work_never_changes_results() {
    // Same search with and without the memo, serial closure evaluator:
    // identical fronts, strictly no more unique evaluations with the memo.
    let cfg_on = NsgaConfig {
        pop_size: 14,
        generations: 10,
        ..Default::default()
    };
    let cfg_off = NsgaConfig {
        memoize: false,
        ..cfg_on.clone()
    };
    let f = |g: &[bool]| {
        let ones = g.iter().filter(|&&b| b).count() as f64;
        vec![ones, g.len() as f64 - ones]
    };
    let (on, s_on) = nsga::run_batched(9, &cfg_on, &mut SerialFitness(f));
    let (off, s_off) = nsga::run_batched(9, &cfg_off, &mut SerialFitness(f));
    assert_fronts_identical(&on, &off, "memo on vs off");
    assert!(s_on.evals <= s_off.evals);
    assert_eq!(s_off.evals, s_off.requested);
}

#[test]
fn batched_matches_serial_across_seeds() {
    let f = |g: &[bool]| {
        vec![
            g.iter().filter(|&&b| b).count() as f64,
            g.iter().take_while(|&&b| !b).count() as f64,
        ]
    };
    for seed in [1u64, 77, 4242, 0xA5D0] {
        let cfg = NsgaConfig {
            pop_size: 14,
            generations: 12,
            seed,
            ..Default::default()
        };
        let serial = nsga::run(10, &cfg, f);
        let (batched, _) = nsga::run_batched(10, &cfg, &mut SerialFitness(f));
        assert_fronts_identical(&serial, &batched, &format!("seed {seed}"));
    }
}

#[test]
fn parallel_search_is_seed_deterministic() {
    // Two runs at the same seed and thread count agree exactly — and so
    // do runs at *different* thread counts (thread count only changes
    // who computes each objective, never what is computed).
    let m = rand_model(35, 10, 5, 3);
    let split = rand_split(3, &m, 48);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
    let cfg = NsgaConfig {
        pop_size: 10,
        generations: 6,
        ..Default::default()
    };
    let (a, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cfg, 4);
    let (b, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cfg, 4);
    assert_fronts_identical(&a, &b, "same seed, same threads");
    let (c, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cfg, 2);
    assert_fronts_identical(&a, &c, "same seed, different threads");
}

// ---------------------------------------------------------------------------
// NSGA-II structural invariants
// ---------------------------------------------------------------------------

#[test]
fn rank_correctness_on_hand_built_fronts() {
    // Three nested fronts with known membership.
    let mut pop = vec![
        mk(vec![4.0, 1.0]), // front 0 (extreme)
        mk(vec![1.0, 4.0]), // front 0 (extreme)
        mk(vec![3.0, 3.0]), // front 0 (knee)
        mk(vec![2.0, 2.0]), // front 1 (dominated by the knee only)
        mk(vec![3.0, 0.5]), // front 1 (dominated by [4,1] and [3,3])
        mk(vec![1.0, 1.0]), // front 2
        mk(vec![0.0, 0.0]), // front 3
    ];
    let fronts = non_dominated_sort(&mut pop);
    assert_eq!(fronts.len(), 4);
    assert_eq!(fronts[0], vec![0, 1, 2]);
    assert_eq!(fronts[1], vec![3, 4]);
    assert_eq!(fronts[2], vec![5]);
    assert_eq!(fronts[3], vec![6]);
    for (rank, front) in fronts.iter().enumerate() {
        for &i in front {
            assert_eq!(pop[i].rank, rank);
        }
    }
}

#[test]
fn rank_invariants_on_random_populations() {
    check("non-dominated sort rank invariants", 150, |g: &mut Gen| {
        let n = g.usize_in(1..=24);
        let m = g.usize_in(1..=3);
        let mut pop: Vec<Individual> = (0..n)
            .map(|_| mk((0..m).map(|_| g.i32_in(0..=4) as f64).collect()))
            .collect();
        let fronts = non_dominated_sort(&mut pop);
        // Every individual lands in exactly one front.
        let total: usize = fronts.iter().map(|f| f.len()).sum();
        if total != n {
            return false;
        }
        // No domination within a front; ranks match front index.
        for (rank, front) in fronts.iter().enumerate() {
            for &i in front {
                if pop[i].rank != rank {
                    return false;
                }
                for &j in front {
                    if i != j && dominates(&pop[i].objectives, &pop[j].objectives) {
                        return false;
                    }
                }
            }
        }
        // Every member of front k > 0 is dominated by someone in front k-1.
        for k in 1..fronts.len() {
            for &i in &fronts[k] {
                let covered = fronts[k - 1]
                    .iter()
                    .any(|&j| dominates(&pop[j].objectives, &pop[i].objectives));
                if !covered {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn crowding_boundary_handling() {
    // Fronts of size 1 and 2: every member is a boundary point.
    let mut pop = vec![mk(vec![1.0, 2.0])];
    crowding_distance(&mut pop, &[0]);
    assert!(pop[0].crowding.is_infinite());

    let mut pop = vec![mk(vec![1.0, 2.0]), mk(vec![2.0, 1.0])];
    crowding_distance(&mut pop, &[0, 1]);
    assert!(pop[0].crowding.is_infinite() && pop[1].crowding.is_infinite());

    // Degenerate front (all objectives identical): the guarded span must
    // keep interior distances finite and NaN-free.
    let mut pop: Vec<Individual> = (0..5).map(|_| mk(vec![3.0, 3.0])).collect();
    let front: Vec<usize> = (0..5).collect();
    crowding_distance(&mut pop, &front);
    let interior = pop.iter().filter(|i| i.crowding.is_finite()).count();
    assert_eq!(interior, 3, "exactly the non-extreme members stay finite");
    assert!(pop.iter().all(|i| !i.crowding.is_nan()));

    // Interior points of a spread front get positive, finite crowding;
    // extremes are infinite regardless of objective count.
    let mut pop = vec![
        mk(vec![0.0, 6.0]),
        mk(vec![1.0, 4.0]),
        mk(vec![4.0, 1.0]),
        mk(vec![6.0, 0.0]),
    ];
    let front: Vec<usize> = (0..4).collect();
    crowding_distance(&mut pop, &front);
    assert!(pop[0].crowding.is_infinite() && pop[3].crowding.is_infinite());
    assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    assert!(pop[2].crowding.is_finite() && pop[2].crowding > 0.0);
}

#[test]
fn run_is_seed_deterministic_and_seed_sensitive() {
    let f = |g: &[bool]| vec![g.iter().filter(|&&b| b).count() as f64];
    let cfg = NsgaConfig {
        pop_size: 12,
        generations: 8,
        ..Default::default()
    };
    let a = nsga::run(8, &cfg, f);
    let b = nsga::run(8, &cfg, f);
    assert_fronts_identical(&a, &b, "same seed, nsga::run");
    // A different seed must still yield a valid mutually non-dominated
    // front (genomes may or may not coincide — only validity is asserted).
    let other = NsgaConfig {
        seed: 0xBEEF,
        ..cfg.clone()
    };
    let c = nsga::run(8, &other, f);
    for x in &c {
        for y in &c {
            assert!(!dominates(&x.objectives, &y.objectives) || x.genome == y.genome);
        }
    }
}

// ---------------------------------------------------------------------------
// Third objective: measured energy (--energy-objective)
// ---------------------------------------------------------------------------

/// Deterministic stand-in for the coordinator's measured-energy closure:
/// mask-dependent, accuracy-independent, and cheap.  The real pipeline
/// plugs circuit synthesis + activity-profiled simulation in here; the
/// search machinery under test is identical either way.
fn fake_energy(mask: &[u8]) -> f64 {
    mask.iter()
        .enumerate()
        .map(|(i, &b)| if b == 0 { (i + 2) as f64 } else { 0.3 })
        .sum()
}

#[test]
fn energy_objective_front_bit_identical_serial_vs_batched() {
    let m = rand_model(36, 12, 7, 3);
    let split = rand_split(13, &m, 64);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
    let cfg = NsgaConfig {
        pop_size: 14,
        generations: 8,
        ..Default::default()
    };
    let serial = approx::explore_energy(
        m.hidden,
        &cfg,
        |mask| m.accuracy(&split.xs, &split.ys, &fm, mask, &tables),
        &fake_energy,
    );
    assert!(!serial.is_empty());
    for ind in &serial {
        assert_eq!(ind.objectives.len(), 3, "energy objective makes 3-tuples");
        let mask: Vec<u8> = ind.genome.iter().map(|&b| b as u8).collect();
        assert_eq!(
            ind.objectives[2],
            -fake_energy(&mask),
            "objectives[2] is the negated energy of the genome's mask"
        );
    }
    for threads in [1usize, 3, 8] {
        let (parallel, stats) =
            approx::explore_parallel_energy(&m, &split, &fm, &tables, &cfg, threads, &fake_energy);
        assert_fronts_identical(&serial, &parallel, &format!("3-obj, {threads} threads"));
        assert_eq!(stats.evals + stats.cache_hits, stats.requested);
    }
}

#[test]
fn memo_accounting_holds_with_energy_objective_on() {
    // 6 genome bits -> 64 possible masks, but pop 14 × (6 + 1 initial)
    // generations = 98 requested evaluations: the 3-tuple memo *must*
    // record hits, and two runs at different thread counts must agree on
    // every counter (the cache key is the genome, never the thread).
    let m = rand_model(37, 10, 6, 3);
    let split = rand_split(17, &m, 48);
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
    let cfg = NsgaConfig {
        pop_size: 14,
        generations: 6,
        ..Default::default()
    };
    let run = |threads: usize| {
        approx::explore_parallel_energy(&m, &split, &fm, &tables, &cfg, threads, &fake_energy)
    };
    let (a, sa) = run(4);
    let (b, sb) = run(2);
    assert_fronts_identical(&a, &b, "3-obj memo, 4 vs 2 threads");
    assert_eq!(sa.requested, cfg.pop_size * (cfg.generations + 1));
    assert_eq!(sa.requested, sb.requested);
    assert_eq!(sa.evals, sb.evals);
    assert_eq!(sa.cache_hits, sb.cache_hits);
    assert_eq!(sa.evals + sa.cache_hits, sa.requested);
    assert!(
        sa.cache_hits > 0,
        "98 requests over 64 possible genomes must hit the memo"
    );
    assert!(sa.hit_rate() > 0.0 && sa.hit_rate() < 1.0);
}

#[test]
fn rank_and_crowding_on_three_objective_tuples() {
    // Hand-built 3-objective population with known domination structure
    // (maximization on every axis, as in (#approx, acc, -energy)).
    let mut pop = vec![
        mk(vec![3.0, 2.0, 1.0]), // front 0 — best on objective 0
        mk(vec![1.0, 3.0, 2.0]), // front 0 — best on objective 1
        mk(vec![2.0, 1.0, 3.0]), // front 0 — best on objective 2
        mk(vec![2.0, 2.0, 1.0]), // front 1 — dominated by [3,2,1] only
        mk(vec![1.0, 1.0, 1.0]), // front 2 — dominated by [2,2,1]
        mk(vec![0.0, 0.0, 0.0]), // front 3 — dominated by everything
    ];
    let fronts = non_dominated_sort(&mut pop);
    assert_eq!(fronts.len(), 4);
    assert_eq!(fronts[0], vec![0, 1, 2]);
    assert_eq!(fronts[1], vec![3]);
    assert_eq!(fronts[2], vec![4]);
    assert_eq!(fronts[3], vec![5]);
    for (rank, front) in fronts.iter().enumerate() {
        for &i in front {
            assert_eq!(pop[i].rank, rank);
        }
    }

    // Crowding over 3-tuples: members that are extreme on *any* objective
    // go infinite; members interior on every objective stay finite > 0.
    let mut pop = vec![
        mk(vec![0.0, 6.0, 5.0]), // extreme on all three axes
        mk(vec![1.0, 4.0, 4.0]), // interior everywhere
        mk(vec![4.0, 1.0, 2.0]), // interior everywhere
        mk(vec![6.0, 0.0, 1.0]), // extreme on all three axes
    ];
    let front: Vec<usize> = (0..4).collect();
    crowding_distance(&mut pop, &front);
    assert!(pop[0].crowding.is_infinite() && pop[3].crowding.is_infinite());
    assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    assert!(pop[2].crowding.is_finite() && pop[2].crowding > 0.0);
}
