//! End-to-end pipeline integration test: the full framework on a real
//! dataset with a reduced NSGA budget.  Validates cross-stage invariants
//! the unit tests can't see (RFP schedule feeding circuit generation,
//! NSGA masks feeding hybrid circuits, gate-level accuracy consistency).

use printed_mlp::coordinator::{run_dataset, PipelineConfig};
use printed_mlp::data::ArtifactStore;

fn store() -> Option<ArtifactStore> {
    let s = ArtifactStore::discover();
    if s.has("spectf") {
        Some(s)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn fast_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.nsga.pop_size = 10;
    cfg.nsga.generations = 6;
    cfg.fit_subset = 256;
    cfg.cache = false;
    cfg
}

#[test]
fn pipeline_invariants_on_spectf() {
    let Some(store) = store() else { return };
    let cfg = fast_cfg();
    let out = run_dataset(&store, "spectf", &cfg).unwrap();

    // RFP invariants.
    assert!(out.rfp.kept >= 1 && out.rfp.kept <= out.rfp.order.len());
    assert_eq!(out.rfp.active.len(), out.rfp.kept);
    assert_eq!(
        out.rfp.feat_mask.iter().filter(|&&m| m == 1).count(),
        out.rfp.kept
    );
    assert!(out.rfp.accuracy >= out.rfp.threshold || out.rfp.kept == out.rfp.order.len());

    // Selections are monotone in the drop budget.
    for w in out.selections.windows(2) {
        assert!(w[0].0 < w[1].0);
        assert!(w[0].1.n_approx <= w[1].1.n_approx);
    }

    // Architecture ranking (the paper's core claim at dataset scale).
    assert!(out.ours.report.area_cm2 < out.sota.report.area_cm2);
    assert!(out.ours.report.power_mw < out.sota.report.power_mw);
    // Hybrid never larger than multi-cycle.
    for (_, h) in &out.hybrids {
        assert!(h.report.area_cm2 <= out.ours.report.area_cm2 + 1e-9);
    }

    // Sequential designs share the cycle contract.
    assert_eq!(out.ours.cycles, out.sota.cycles);
    assert_eq!(out.comb.cycles, 1);

    // Gate-level accuracy sits in a sane band relative to the recorded
    // quantized accuracy (RFP trades a bounded amount away).
    assert!(out.ours.test_acc > out.quant_test_acc - 0.15);

    // Timing closes at the paper's synthesis clocks.
    assert!(
        out.ours.report.crit_path_ms <= out.ours.clock_ms,
        "multicycle misses its clock: {} > {}",
        out.ours.report.crit_path_ms,
        out.ours.clock_ms
    );
    assert!(out.comb.report.crit_path_ms <= out.comb.clock_ms);
}

#[test]
fn pipeline_native_matches_pjrt_decisions() {
    // The same pipeline driven by the native evaluator must make identical
    // RFP decisions (bit-exact evaluators => identical accuracies).
    let Some(store) = store() else { return };
    let mut cfg = fast_cfg();
    let a = run_dataset(&store, "spectf", &cfg).unwrap();
    cfg.backend = printed_mlp::runtime::Backend::Native;
    let b = run_dataset(&store, "spectf", &cfg).unwrap();
    assert_eq!(a.rfp.kept, b.rfp.kept);
    assert_eq!(a.rfp.order, b.rfp.order);
    assert_eq!(a.rfp.accuracy, b.rfp.accuracy);
    for ((_, sa), (_, sb)) in a.selections.iter().zip(&b.selections) {
        assert_eq!(sa.approx_mask, sb.approx_mask);
    }
}

#[test]
fn greedy_and_bisect_rfp_agree_on_real_data() {
    let Some(store) = store() else { return };
    let mut cfg = fast_cfg();
    cfg.rfp_strategy = printed_mlp::rfp::Strategy::Greedy;
    let g = run_dataset(&store, "spectf", &cfg).unwrap();
    cfg.rfp_strategy = printed_mlp::rfp::Strategy::Bisect;
    let b = run_dataset(&store, "spectf", &cfg).unwrap();
    // Bisect assumes monotone accuracy-vs-N; on real curves it may land on
    // a slightly different frontier point, but both must meet the
    // threshold and bisect must not do more evaluations.
    assert!(g.rfp.accuracy >= g.rfp.threshold);
    assert!(b.rfp.accuracy >= b.rfp.threshold);
    assert!(b.rfp.evals <= g.rfp.evals);
}
