//! Differential tests for activity-gated (event-driven) compiled
//! simulation (`sim` §Gating): skipping homogeneous opcode runs whose
//! input blocks did not toggle must be bit-identical to the ungated
//! simulator — on random netlists, at every super-lane width, thread
//! count, and fault list — and must actually skip work on the
//! sequential protocol (held inputs during drain + settle fixpoint).
//!
//! Artifact-free (random netlists and `QuantModel`s from the
//! mini-propcheck kit), so this suite runs in tier-1.

mod common;

use std::sync::Arc;

use common::rand_model;
use printed_mlp::circuits::seq_multicycle;
use printed_mlp::netlist::{NetId, Netlist, Port};
use printed_mlp::sim::fault::{default_roles, FaultList};
use printed_mlp::sim::{batch, testbench, Sim, SimPlan};
use printed_mlp::util::prng::Rng;
use printed_mlp::util::propcheck::{check, rand_netlist};

fn port<'a>(ports: &'a [Port], name: &str) -> &'a [u32] {
    &ports
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing port {name}"))
        .bits
}

/// A deterministic multi-step stimulus for a random netlist: some steps
/// re-drive every input, some hold a subset (held inputs are what gating
/// can skip).  Seeded per block so every runner sees identical lanes.
fn rand_drive<'a>(
    ins: &'a [NetId],
    obs: &'a [NetId],
    steps: usize,
    seed: u64,
) -> impl Fn(&mut Sim, usize, usize) -> Vec<u16> + Sync + 'a {
    move |sim, base, lanes| {
        let mut r = Rng::new(seed ^ (base as u64).wrapping_mul(0x9E37_79B9));
        let mut scratch = Vec::with_capacity(lanes);
        for step in 0..steps {
            for &inp in ins {
                // Hold roughly half the inputs after the first step so
                // clean input blocks actually occur.
                if step > 0 && r.chance(0.5) {
                    continue;
                }
                scratch.clear();
                for _ in 0..lanes {
                    scratch.push(r.below(2) as i64);
                }
                sim.set_word_lanes(&[inp], &scratch);
            }
            sim.step();
        }
        sim.settle();
        (0..lanes).map(|lane| sim.get_word_lane(obs, lane) as u16).collect()
    }
}

#[test]
fn gated_matches_ungated_on_random_netlists() {
    // The core differential: gated == ungated bit-for-bit on random
    // netlists (feedback registers, buffer chains, folded constants)
    // across widths x threads x fault lists.
    check("gated == ungated (random netlists)", 6, |g| {
        let net: Netlist = rand_netlist(g);
        let plan = Arc::new(SimPlan::compiled(&net));
        let ins: Vec<NetId> = net.inputs.iter().map(|p| p.bits[0]).collect();
        let obs: Vec<NetId> = port(&net.outputs, "obs").to_vec();
        let steps = g.usize_in(2..=5);
        let seed = g.rng().below(u64::MAX);
        let n = g.usize_in(1..=150);
        let fl = FaultList::sample(&plan, &net, &default_roles(), 2, 2, 0.2, seed ^ 1);
        let faults = [None, Some(&fl)];
        for w in [1usize, 2, 4, 8] {
            for threads in [1usize, 3] {
                for fault in faults {
                    let drive = rand_drive(&ins, &obs, steps, seed);
                    let want =
                        batch::run_sharded_wide_faulted(&plan, n, threads, w, fault, &drive);
                    let (got, stats) =
                        batch::run_sharded_wide_gated(&plan, n, threads, w, fault, &drive);
                    if want != got {
                        return false;
                    }
                    // A plan with surviving ops must execute something
                    // on the first (all-dirty) pass, never lose runs.
                    let n_ops = plan.compiled_plan().map_or(0, |c| c.n_ops());
                    if n_ops > 0 && stats.executed == 0 {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn gated_sequential_protocol_matches_and_skips() {
    // The real workload: the multi-cycle sequential protocol holds the
    // feature bus during drain cycles and settles to a fixpoint, so a
    // correct gate must both agree bit-for-bit and report skipped > 0.
    let m = rand_model(31, 8, 4, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let plan = Arc::new(SimPlan::compiled(&circ.netlist));
    let n = 130;
    let mut r = Rng::new(97);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let want = testbench::run_sequential_plan(&circ, &plan, &xs, n, m.features, 1, 1);
    for w in [1usize, 2, 4, 8] {
        for threads in [1usize, 4] {
            let (got, stats) = testbench::run_sequential_plan_gated(
                &circ, &plan, &xs, n, m.features, threads, w, None,
            );
            assert_eq!(want, got, "gated diverged at w={w} threads={threads}");
            assert!(stats.executed > 0, "w={w} threads={threads}: nothing executed");
            assert!(
                stats.skipped > 0,
                "w={w} threads={threads}: held inputs + settle must skip some runs"
            );
            let rate = stats.skip_rate();
            assert!(
                rate > 0.0 && rate < 1.0,
                "w={w} threads={threads}: skip rate {rate} out of range"
            );
        }
    }
}

#[test]
fn gated_composes_with_fault_run_splitting() {
    // Stuck-at faults split compiled runs at the fault site; the gate
    // table is rebuilt from the *active* run table, so gating must stay
    // bit-identical on the faulted sequential path too.
    let m = rand_model(47, 7, 3, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let plan = Arc::new(SimPlan::compiled(&circ.netlist));
    let n = 70;
    let mut r = Rng::new(53);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let fl = FaultList::sample(&plan, &circ.netlist, &default_roles(), 6, 4, 0.15, 19);
    assert!(!fl.is_empty(), "fault sampler found no sites");
    for (threads, w) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let want = testbench::run_sequential_plan_faulted(
            &circ, &plan, &xs, n, m.features, threads, w, Some(&fl),
        );
        let (got, _) = testbench::run_sequential_plan_gated(
            &circ, &plan, &xs, n, m.features, threads, w, Some(&fl),
        );
        assert_eq!(want, got, "faulted gated diverged at w={w} threads={threads}");
    }
}

#[test]
fn gating_is_a_noop_on_interpreted_plans() {
    // The interpreted reference simulator has no run table to gate; the
    // gated entry point must pass through untouched with zero stats.
    let m = rand_model(59, 6, 3, 2);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let interp = Arc::new(SimPlan::new(&circ.netlist));
    let compiled = Arc::new(SimPlan::compiled(&circ.netlist));
    let n = 40;
    let mut r = Rng::new(11);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let want = testbench::run_sequential_plan(&circ, &compiled, &xs, n, m.features, 2, 1);
    let (got, stats) =
        testbench::run_sequential_plan_gated(&circ, &interp, &xs, n, m.features, 2, 1, None);
    assert_eq!(want, got, "interpreted gated pass-through diverged");
    assert_eq!(stats.executed, 0, "interpreted plans have no runs to count");
    assert_eq!(stats.skipped, 0, "interpreted plans must not report skips");
}
