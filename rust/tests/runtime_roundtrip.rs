//! Cross-layer validation: the AOT-compiled JAX/Pallas artifact executed
//! through PJRT must agree bit-exactly with the native Rust functional
//! model on real datasets, including under feature masks and neuron
//! approximation (the exact surface RFP and NSGA-II exercise).
//!
//! Requires `make artifacts` (skips politely otherwise).

use printed_mlp::data::ArtifactStore;
use printed_mlp::model::importance;
use printed_mlp::model::ApproxTables;
use printed_mlp::runtime::{Engine, NativeEvaluator, PjrtEvaluator, BATCH_THROUGHPUT};
use printed_mlp::util::prng::Rng;

fn store() -> Option<ArtifactStore> {
    let s = ArtifactStore::discover();
    if s.has("spectf") {
        Some(s)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_matches_native_exact() {
    let Some(store) = store() else { return };
    let engine = Engine::cpu().unwrap();
    for name in ["spectf", "gas"] {
        let model = store.model(name).unwrap();
        let ds = store.dataset(name).unwrap();
        let eval = PjrtEvaluator::new(
            &engine,
            &store.hlo_path(name, BATCH_THROUGHPUT),
            &model,
            BATCH_THROUGHPUT,
        )
        .unwrap();
        let native = NativeEvaluator { model: &model };

        let split = ds.test.head(300); // covers a padded partial chunk
        let fm = vec![1u8; model.features];
        let am = vec![0u8; model.hidden];
        let t = ApproxTables::disabled(model.hidden);
        let got = eval.predict(&split.xs, split.len(), &fm, &am, &t).unwrap();
        let want = native.predict(&split.xs, split.len(), &fm, &am, &t);
        assert_eq!(got, want, "{name}: PJRT and native predictions diverge");
    }
}

#[test]
fn pjrt_matches_native_under_masks_and_approx() {
    let Some(store) = store() else { return };
    let engine = Engine::cpu().unwrap();
    let name = "spectf";
    let model = store.model(name).unwrap();
    let ds = store.dataset(name).unwrap();
    let eval = PjrtEvaluator::new(
        &engine,
        &store.hlo_path(name, BATCH_THROUGHPUT),
        &model,
        BATCH_THROUGHPUT,
    )
    .unwrap();
    let native = NativeEvaluator { model: &model };
    let split = ds.test.head(256);

    let mut rng = Rng::new(2024);
    for trial in 0..5 {
        // Random feature mask (keep ~80%) and random approx mask.
        let fm: Vec<u8> = (0..model.features)
            .map(|_| if rng.chance(0.8) { 1 } else { 0 })
            .collect();
        let am: Vec<u8> = (0..model.hidden)
            .map(|_| if rng.chance(0.5) { 1 } else { 0 })
            .collect();
        let tables = importance::approx_tables(&model, &split.xs, split.len(), &fm);

        let got = eval.predict(&split.xs, split.len(), &fm, &am, &tables).unwrap();
        let want = native.predict(&split.xs, split.len(), &fm, &am, &tables);
        assert_eq!(got, want, "trial {trial}: divergence under masks");
    }
}

#[test]
fn pjrt_latency_artifact_works() {
    let Some(store) = store() else { return };
    let engine = Engine::cpu().unwrap();
    let model = store.model("spectf").unwrap();
    let ds = store.dataset("spectf").unwrap();
    let eval = PjrtEvaluator::new(&engine, &store.hlo_path("spectf", 1), &model, 1).unwrap();
    let native = NativeEvaluator { model: &model };
    let fm = vec![1u8; model.features];
    let am = vec![0u8; model.hidden];
    let t = ApproxTables::disabled(model.hidden);
    let split = ds.test.head(16);
    let got = eval.predict(&split.xs, split.len(), &fm, &am, &t).unwrap();
    assert_eq!(got, native.predict(&split.xs, split.len(), &fm, &am, &t));
}

#[test]
fn accuracy_matches_recorded_test_acc() {
    // The accuracy the Python trainer recorded (via the jnp oracle) must be
    // reproduced by the Rust functional model — three implementations of
    // the same semantics agreeing on the paper's headline metric.
    let Some(store) = store() else { return };
    for name in printed_mlp::data::DATASET_ORDER {
        let model = store.model(name).unwrap();
        let ds = store.dataset(name).unwrap();
        let native = NativeEvaluator { model: &model };
        let fm = vec![1u8; model.features];
        let am = vec![0u8; model.hidden];
        let t = ApproxTables::disabled(model.hidden);
        let acc = native.accuracy(&ds.test, &fm, &am, &t);
        assert!(
            // The Python side records float32 accuracies; allow f32 eps.
            (acc - model.test_acc).abs() < 1e-6,
            "{name}: native acc {acc} != recorded {}",
            model.test_acc
        );
    }
}
