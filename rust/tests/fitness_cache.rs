//! Differential suite for the delta-logit fitness cache
//! (`model::cache::FitnessCache`, DESIGN.md §Perf).
//!
//! The contract under test: a genome evaluation through the cache —
//! all-exact baseline logits plus the selected per-neuron delta columns,
//! re-applied incrementally along a mask walk — is **bit-identical** to
//! the scalar reference (`QuantModel::forward` per sample) for every
//! model shape, RFP feature mask, approximation mask, and split,
//! including the all-exact, all-approx, and pruned-output-weight cases;
//! and an NSGA-II search over the cached evaluator returns the same
//! Pareto front as the serial scalar oracle at equal seeds, with the
//! 2- and 3-objective (`--energy-objective`) paths and the
//! `PRINTED_MLP_NO_FITNESS_CACHE` escape hatch all covered.
//!
//! Artifact-free (random `QuantModel`s), so this suite runs in tier-1.

mod common;

use common::rand_model;
use printed_mlp::approx;
use printed_mlp::data::Split;
use printed_mlp::model::cache::FitnessCache;
use printed_mlp::model::{ApproxTables, QuantModel};
use printed_mlp::nsga::{Individual, NsgaConfig};
use printed_mlp::util::propcheck::{check, Gen};

/// Scalar oracle: per-sample predictions through the reference
/// `forward` (not the blocked batch kernel, which has its own
/// differential tests in `model::tests`).
fn scalar_predictions(
    m: &QuantModel,
    xs: &[u8],
    n: usize,
    fm: &[u8],
    am: &[u8],
    tables: &ApproxTables,
) -> Vec<i32> {
    let mut x = vec![0i32; m.features];
    (0..n)
        .map(|i| {
            for (xj, &v) in x.iter_mut().zip(&xs[i * m.features..(i + 1) * m.features]) {
                *xj = v as i32;
            }
            m.forward(&x, fm, am, tables).0 as i32
        })
        .collect()
}

fn assert_fronts_identical(a: &[Individual], b: &[Individual], what: &str) {
    assert_eq!(a.len(), b.len(), "front size differs: {what}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.genome, y.genome, "genome differs: {what}");
        assert_eq!(x.objectives, y.objectives, "objectives differ: {what}");
    }
}

/// Deterministic stand-in for the measured-energy closure (matches
/// `tests/nsga_parallel.rs`).
fn fake_energy(mask: &[u8]) -> f64 {
    mask.iter()
        .enumerate()
        .map(|(i, &b)| if b == 0 { (i + 2) as f64 } else { 0.3 })
        .sum()
}

// ---------------------------------------------------------------------------
// Cache vs scalar forward, property-checked over random everything
// ---------------------------------------------------------------------------

#[test]
fn cached_accuracy_and_predictions_match_scalar_oracle() {
    check("delta-logit cache == scalar forward", 60, |g: &mut Gen| {
        let features = g.usize_in(1..=20);
        let hidden = g.usize_in(1..=12);
        let classes = g.usize_in(1..=5);
        let n = g.usize_in(1..=40);
        let seed = g.rng().below(1 << 20);
        let m = rand_model(seed, features, hidden, classes);
        let xs: Vec<u8> = (0..n * features).map(|_| g.rng().below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| g.rng().below(classes as u64) as u16).collect();
        // RFP mask with occasional pruned features (the cache must bake
        // the same feature gating into base and delta columns).
        let fm: Vec<u8> = (0..features).map(|_| g.rng().chance(0.8) as u8).collect();
        let tables = approx::build_tables(&m, &xs, n, &fm);
        let cache = FitnessCache::build(&m, &xs, &ys, &fm, &tables);
        let mut scratch = cache.new_scratch();
        let mut preds = Vec::new();
        // One shared scratch walked over the whole mask sequence, so the
        // incremental parent→child diff path is what gets exercised; the
        // walk pins the all-exact and all-approx endpoints.
        let mut masks: Vec<Vec<u8>> = vec![vec![0u8; hidden]];
        for _ in 0..5 {
            masks.push((0..hidden).map(|_| g.bool() as u8).collect());
        }
        masks.push(vec![1u8; hidden]);
        for mask in &masks {
            if cache.accuracy(&mut scratch, mask) != m.accuracy(&xs, &ys, &fm, mask, &tables) {
                return false;
            }
            cache.predict_into(&mut scratch, mask, &mut preds);
            if preds != scalar_predictions(&m, &xs, n, &fm, mask, &tables) {
                return false;
            }
        }
        true
    });
}

#[test]
fn pruned_output_weights_skip_columns_without_changing_results() {
    // Zeroing a neuron's entire output-weight column prunes its delta
    // columns (flagged zero, skipped by apply) — and toggling that
    // neuron must still agree with the scalar oracle, which also sees
    // the zero weights.
    let mut m = rand_model(91, 10, 6, 4);
    for c in 0..m.classes {
        m.w2s[c * m.hidden + 2] = 0;
    }
    let n = 48usize;
    let mut r = printed_mlp::util::prng::Rng::new(14);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let ys: Vec<u16> = (0..n).map(|_| r.below(m.classes as u64) as u16).collect();
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &xs, n, &fm);
    let cache = FitnessCache::build(&m, &xs, &ys, &fm, &tables);
    assert!(
        cache.zero_column_rate() >= 1.0 / m.hidden as f64 - 1e-12,
        "neuron 2's columns must all be flagged zero"
    );
    let mut scratch = cache.new_scratch();
    for mask in [
        vec![0, 0, 1, 0, 0, 0],
        vec![1, 0, 1, 1, 0, 0],
        vec![1, 0, 0, 1, 0, 0],
        vec![1u8; 6],
    ] {
        assert_eq!(
            cache.accuracy(&mut scratch, &mask),
            m.accuracy(&xs, &ys, &fm, &mask, &tables),
            "mask {mask:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// NSGA fronts: cached vs scalar oracle, 2- and 3-objective, env hatch
// ---------------------------------------------------------------------------

fn search_fixture(seed: u64) -> (QuantModel, Split, Vec<u8>, ApproxTables) {
    let m = rand_model(seed, 12, 8, 3);
    let mut r = printed_mlp::util::prng::Rng::new(seed ^ 0xF00D);
    let n = 64usize;
    let split = Split {
        xs: (0..n * m.features).map(|_| r.below(16) as u8).collect(),
        ys: (0..n).map(|_| r.below(m.classes as u64) as u16).collect(),
        features: m.features,
    };
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
    (m, split, fm, tables)
}

#[test]
fn cached_search_front_matches_serial_scalar_oracle() {
    let (m, split, fm, tables) = search_fixture(92);
    let cached = NsgaConfig {
        pop_size: 12,
        generations: 8,
        ..Default::default()
    };
    let scalar = NsgaConfig {
        cached_fitness: false,
        ..cached.clone()
    };
    let serial = approx::explore(m.hidden, &cached, |mask| {
        m.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
    });
    for threads in [1usize, 2, 4] {
        let (c, cs) = approx::explore_parallel(&m, &split, &fm, &tables, &cached, threads);
        let (s, ss) = approx::explore_parallel(&m, &split, &fm, &tables, &scalar, threads);
        assert_fronts_identical(&serial, &c, &format!("cached, {threads} threads"));
        assert_fronts_identical(&serial, &s, &format!("scalar, {threads} threads"));
        // The cache changes how objectives are computed, never which
        // genomes get evaluated: memo accounting is path-independent.
        assert_eq!(cs.evals, ss.evals);
        assert_eq!(cs.cache_hits, ss.cache_hits);
        assert_eq!(cs.requested, ss.requested);
    }
}

#[test]
fn cached_search_front_matches_oracle_with_energy_objective() {
    let (m, split, fm, tables) = search_fixture(93);
    let cached = NsgaConfig {
        pop_size: 10,
        generations: 6,
        ..Default::default()
    };
    let scalar = NsgaConfig {
        cached_fitness: false,
        ..cached.clone()
    };
    let serial = approx::explore_energy(
        m.hidden,
        &cached,
        |mask| m.accuracy(&split.xs, &split.ys, &fm, mask, &tables),
        &fake_energy,
    );
    for threads in [1usize, 3] {
        let (c, _) = approx::explore_parallel_energy(
            &m, &split, &fm, &tables, &cached, threads, &fake_energy,
        );
        let (s, _) = approx::explore_parallel_energy(
            &m, &split, &fm, &tables, &scalar, threads, &fake_energy,
        );
        assert_fronts_identical(&serial, &c, &format!("3-obj cached, {threads} threads"));
        assert_fronts_identical(&serial, &s, &format!("3-obj scalar, {threads} threads"));
    }
}

#[test]
fn env_hatch_forces_scalar_path_with_identical_front() {
    // PRINTED_MLP_NO_FITNESS_CACHE is consulted per batch; flipping it
    // mid-process must only change *how* fitness is computed.  (Other
    // tests racing on this var are safe for the same reason: both paths
    // are bit-identical.)
    let (m, split, fm, tables) = search_fixture(94);
    let cfg = NsgaConfig {
        pop_size: 10,
        generations: 5,
        ..Default::default()
    };
    let serial = approx::explore(m.hidden, &cfg, |mask| {
        m.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
    });
    std::env::set_var("PRINTED_MLP_NO_FITNESS_CACHE", "1");
    assert!(approx::fitness_cache_env_disabled());
    let (hatched, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cfg, 2);
    std::env::remove_var("PRINTED_MLP_NO_FITNESS_CACHE");
    assert!(!approx::fitness_cache_env_disabled());
    let (cached, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cfg, 2);
    assert_fronts_identical(&serial, &hatched, "env hatch on");
    assert_fronts_identical(&serial, &cached, "env hatch off");
}

#[test]
fn empty_and_degenerate_splits_are_harmless() {
    // n = 0 and single-sample splits through the full search machinery.
    let m = rand_model(95, 6, 4, 3);
    let fm = vec![1u8; m.features];
    for n in [0usize, 1] {
        let mut r = printed_mlp::util::prng::Rng::new(n as u64 + 3);
        let split = Split {
            xs: (0..n * m.features).map(|_| r.below(16) as u8).collect(),
            ys: (0..n).map(|_| r.below(m.classes as u64) as u16).collect(),
            features: m.features,
        };
        let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);
        let cfg = NsgaConfig {
            pop_size: 8,
            generations: 3,
            ..Default::default()
        };
        let serial = approx::explore(m.hidden, &cfg, |mask| {
            m.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
        });
        let (par, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cfg, 2);
        assert_fronts_identical(&serial, &par, &format!("n = {n}"));
    }
}
