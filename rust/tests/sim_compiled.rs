//! Differential tests for the compiled gate-level simulator: the
//! micro-op-compiled path (`SimPlan::compiled` — plan-time strength
//! reduction + dense net renumbering + opcode-run scheduling) must be
//! bit-identical on every lane to the interpreted reference oracle
//! (`SimPlan::new`) — over random netlists with DFFs, muxes, constants
//! and buffer chains; over generated multi-cycle circuits sharded across
//! threads with partial final blocks; through the external port-map
//! translation of `set`/`get`/word helpers; and at every super-lane
//! width `W ∈ {1,2,4,8}` (the W-sweep compares each lane word against
//! its own W=1 oracle, which doubles as the lane-isolation property, and
//! a garbage-injection test proves other lanes can never leak in).  Also
//! property-checks that compilation never increases the gate count.
//!
//! Artifact-free, so this suite runs in tier-1.

mod common;

use std::sync::Arc;

use common::rand_model;
use printed_mlp::circuits::seq_multicycle;
use printed_mlp::netlist::{Cell, Netlist, CONST0, CONST1};
use printed_mlp::sim::{testbench, Sim, SimPlan};
use printed_mlp::util::propcheck::{check, rand_netlist};
use printed_mlp::util::prng::Rng;

/// Compare every output-port bit of both simulators across all 64 lanes.
fn outputs_equal(n: &Netlist, a: &Sim, b: &Sim) -> bool {
    n.outputs
        .iter()
        .all(|p| p.bits.iter().all(|&bit| a.get(bit) == b.get(bit)))
}

#[test]
fn compiled_equals_interpreted_on_random_netlists() {
    check("compiled == interpreted over eval/step/reset", 40, |g| {
        let n = rand_netlist(g);
        let mut si = Sim::from_plan(Arc::new(SimPlan::new(&n)));
        let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
        let mut r = Rng::new(g.rng().next_u64());
        si.reset();
        sc.reset();
        let mut ok = outputs_equal(&n, &si, &sc);
        for _cycle in 0..12 {
            // Same 64-lane stimulus into both simulators.
            for port in &n.inputs {
                for &bit in &port.bits {
                    let v = r.next_u64();
                    si.set(bit, v);
                    sc.set(bit, v);
                }
            }
            // Random mix of clocking, pure propagation, and resets.
            match r.below(8) {
                0 => {
                    si.reset();
                    sc.reset();
                }
                1 => {
                    si.eval();
                    sc.eval();
                }
                _ => {
                    si.step();
                    sc.step();
                }
            }
            ok = ok && outputs_equal(&n, &si, &sc);
        }
        ok
    });
}

#[test]
fn compilation_never_increases_gate_count() {
    check("plan compile only shrinks", 60, |g| {
        let n = rand_netlist(g);
        let plan = SimPlan::compiled(&n);
        let cp = plan.compiled_plan().unwrap();
        let n_comb = n.cells.iter().filter(|c| !c.is_seq()).count();
        let n_dff = n.cells.len() - n_comb;
        cp.n_ops() <= n_comb && cp.n_state() <= n_dff && cp.n_dense_nets() <= n.n_nets()
    });
}

#[test]
fn compiled_sharded_partial_blocks_match_interpreted_serial() {
    // 130 samples = two full 64-lane blocks + a 2-lane partial tail at
    // W=1; the compiled plan is shared read-only by every worker.
    let m = rand_model(31, 9, 4, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let interp = Arc::new(SimPlan::new(&circ.netlist));
    let comp = Arc::new(SimPlan::compiled(&circ.netlist));
    let n = 130;
    let mut r = Rng::new(5);
    let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
    let want = testbench::run_sequential_plan(&circ, &interp, &xs, n, m.features, 1, 1);
    for threads in [1usize, 3, 8] {
        let got = testbench::run_sequential_plan(&circ, &comp, &xs, n, m.features, threads, 1);
        assert_eq!(want, got, "threads={threads}");
    }
    // Tiny and exact-block sizes through the same pair of plans.
    for n in [1usize, 63, 64] {
        let head = &xs[..n * m.features];
        let want = testbench::run_sequential_plan(&circ, &interp, head, n, m.features, 1, 1);
        let got = testbench::run_sequential_plan(&circ, &comp, head, n, m.features, 4, 1);
        assert_eq!(want, got, "n={n}");
    }
}

#[test]
fn super_lane_w_sweep_matches_w1_oracle() {
    // The tentpole differential: every width W ∈ {1,2,4,8}, on both the
    // compiled and the interpreted path, serial and sharded, must be
    // bit-identical to the W=1 interpreted oracle — including partial
    // final blocks at every width (n = 130 is partial for every W, and
    // n = 257 adds a 1-lane tail beyond a full W=4 block).
    let m = rand_model(41, 8, 4, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let interp = Arc::new(SimPlan::new(&circ.netlist));
    let comp = Arc::new(SimPlan::compiled(&circ.netlist));
    let n_max = 257;
    let mut r = Rng::new(6);
    let xs: Vec<u8> = (0..n_max * m.features).map(|_| r.below(16) as u8).collect();
    for n in [3usize, 64, 130, 257] {
        let head = &xs[..n * m.features];
        let want = testbench::run_sequential_plan(&circ, &interp, head, n, m.features, 1, 1);
        for w in [1usize, 2, 4, 8] {
            for plan in [&interp, &comp] {
                for threads in [1usize, 3] {
                    let got = testbench::run_sequential_plan(
                        &circ, plan, head, n, m.features, threads, w,
                    );
                    assert_eq!(
                        want,
                        got,
                        "n={n} w={w} threads={threads} compiled={}",
                        plan.is_compiled()
                    );
                }
            }
        }
    }
}

#[test]
fn super_lane_widths_match_oracle_on_random_netlists() {
    // Propcheck differential at every width: drive W independent 64-lane
    // stimulus words through one wide sim and through W separate W=1
    // interpreted-oracle sims; every output word must match its oracle
    // after the same mixed eval/step/reset schedule.  This is both the
    // W-sweep correctness proof and the lane-word isolation property (a
    // word's outputs depend only on its own stimulus).
    check("wide sim == per-word W=1 oracle", 25, |g| {
        let n = rand_netlist(g);
        let w = [2usize, 4, 8][g.rng().usize_below(3)];
        let compiled = g.bool();
        let plan = if compiled {
            Arc::new(SimPlan::compiled(&n))
        } else {
            Arc::new(SimPlan::new(&n))
        };
        let mut wide = Sim::from_plan_wide(plan, w);
        let mut oracles: Vec<Sim> =
            (0..w).map(|_| Sim::from_plan(Arc::new(SimPlan::new(&n)))).collect();
        let mut r = Rng::new(g.rng().next_u64());
        wide.reset();
        for o in oracles.iter_mut() {
            o.reset();
        }
        let mut ok = true;
        for _cycle in 0..10 {
            for port in &n.inputs {
                for &bit in &port.bits {
                    for (j, o) in oracles.iter_mut().enumerate() {
                        let v = r.next_u64();
                        wide.set_lane_word(bit, j, v);
                        o.set(bit, v);
                    }
                }
            }
            match r.below(8) {
                0 => {
                    wide.reset();
                    for o in oracles.iter_mut() {
                        o.reset();
                    }
                }
                1 => {
                    wide.eval();
                    for o in oracles.iter_mut() {
                        o.eval();
                    }
                }
                _ => {
                    wide.step();
                    for o in oracles.iter_mut() {
                        o.step();
                    }
                }
            }
            for port in &n.outputs {
                for &bit in &port.bits {
                    for (j, o) in oracles.iter().enumerate() {
                        ok = ok && wide.get_lane_word(bit, j) == o.get(bit);
                    }
                }
            }
        }
        ok
    });
}

#[test]
fn lane_isolation_garbage_in_other_lanes_never_leaks() {
    // Lane 0 gets a fixed stimulus; every other lane word gets fresh
    // garbage each cycle.  Lane word 0's outputs must be identical to a
    // W=1 run of the same stimulus — garbage cannot leak across lanes.
    let m = rand_model(47, 7, 3, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let net = &circ.netlist;
    let plan = Arc::new(SimPlan::compiled(net));
    let mut wide = Sim::from_plan_wide(plan.clone(), 8);
    let mut narrow = Sim::from_plan(plan);
    let mut stim = Rng::new(33);
    let mut garbage = Rng::new(99);
    wide.reset();
    narrow.reset();
    for cycle in 0..20 {
        for port in &net.inputs {
            for &bit in &port.bits {
                let v = stim.next_u64();
                wide.set_lane_word(bit, 0, v);
                narrow.set(bit, v);
                for j in 1..wide.lane_words() {
                    wide.set_lane_word(bit, j, garbage.next_u64());
                }
            }
        }
        wide.step();
        narrow.step();
        for port in &net.outputs {
            for &bit in &port.bits {
                assert_eq!(
                    wide.get_lane_word(bit, 0),
                    narrow.get(bit),
                    "cycle {cycle}: garbage leaked into lane word 0"
                );
            }
        }
    }
}

#[test]
fn port_map_translates_aliased_constant_and_dead_nets() {
    let mut n = Netlist::new("t");
    let a = n.add_input("a", 1)[0];
    let b = n.add_input("b", 1)[0];
    // Buffer chain: b2 aliases a after collapsing.
    let b1 = n.fresh();
    n.cells.push(Cell::Buf { a, y: b1 });
    let b2 = n.fresh();
    n.cells.push(Cell::Buf { a: b1, y: b2 });
    // Double inverter: i2 aliases b.
    let i1 = n.inv(b);
    let i2 = n.inv(i1);
    // Constant-folded gate (raw push so the builder can't intercept).
    let k = n.fresh();
    n.cells.push(Cell::And2 { a, b: CONST0, y: k });
    let live = n.xor2(a, b);
    n.add_output("alias", vec![b2, i2]);
    n.add_output("konst", vec![k, CONST1]);
    n.add_output("live", vec![live]);
    let mut si = Sim::from_plan(Arc::new(SimPlan::new(&n)));
    let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
    for (pa, pb) in [(0u64, 0u64), (!0u64, 0u64), (0x1234_5678_9ABC_DEF0, !0u64)] {
        for s in [&mut si, &mut sc] {
            s.set(a, pa);
            s.set(b, pb);
            s.eval();
        }
        assert!(outputs_equal(&n, &si, &sc), "a={pa:#x} b={pb:#x}");
        assert_eq!(sc.get(b2), pa, "buffer chain reads its source");
        assert_eq!(sc.get(i2), pb, "double inverter reads its source");
        assert_eq!(sc.get(k), 0, "AND(x,0) reads constant 0");
    }
}

#[test]
fn word_helpers_run_through_the_port_map() {
    // 6-bit adder with a buffered output word: set_word_lanes /
    // get_word_lane(_signed) must agree between the paths.
    let mut n = Netlist::new("t");
    let aw = n.add_input("a", 6);
    let bw = n.add_input("b", 6);
    let sum = printed_mlp::circuits::rtl::add(&mut n, &aw, &bw);
    // Buffer every sum bit so the external word ids are all aliases.
    let buffered: Vec<_> = sum
        .iter()
        .map(|&s| {
            let y = n.fresh();
            n.cells.push(Cell::Buf { a: s, y });
            y
        })
        .collect();
    n.add_output("sum", buffered.clone());
    let mut si = Sim::from_plan(Arc::new(SimPlan::new(&n)));
    let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
    let avals = [-32i64, -1, 0, 1, 31, 5, -17, 12];
    let bvals = [3i64, -3, 0, 31, -32, 7, 7, -1];
    for s in [&mut si, &mut sc] {
        s.set_word_lanes(&aw, &avals);
        s.set_word_lanes(&bw, &bvals);
        s.eval();
    }
    for lane in 0..avals.len() {
        assert_eq!(
            si.get_word_lane_signed(&buffered, lane),
            sc.get_word_lane_signed(&buffered, lane),
            "lane {lane} signed"
        );
        assert_eq!(
            si.get_word_lane(&buffered, lane),
            sc.get_word_lane(&buffered, lane),
            "lane {lane} unsigned"
        );
    }
}

#[test]
fn compiled_plan_reduces_generated_circuits() {
    // Generated circuits are already CSE+DCE-optimized, so the compiled
    // stream can only match or beat their comb cell count — and the dense
    // value vector never exceeds the source net count.
    let m = rand_model(17, 12, 4, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let plan = SimPlan::compiled(&circ.netlist);
    let cp = plan.compiled_plan().unwrap();
    let n_comb = plan.n_cells() - plan.n_dffs();
    assert!(cp.n_ops() <= n_comb, "{} ops vs {} comb cells", cp.n_ops(), n_comb);
    assert!(cp.n_state() <= plan.n_dffs());
    assert!(cp.n_dense_nets() <= circ.netlist.n_nets());
}

#[test]
fn registers_stay_observable_without_output_ports() {
    // A toggler whose q drives no output port: plan compilation must keep
    // the register (DCE roots every q), so `get` observes live state.
    let mut n = Netlist::new("t");
    let (q0, c0) = n.dff_deferred(CONST1, CONST0, false);
    let d0 = n.inv(q0);
    n.set_dff_d(c0, d0);
    let unrelated = n.add_input("a", 1)[0];
    n.add_output("y", vec![unrelated]);
    let mut si = Sim::from_plan(Arc::new(SimPlan::new(&n)));
    let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
    si.reset();
    sc.reset();
    let mut toggled = false;
    for step in 0..5 {
        si.step();
        sc.step();
        assert_eq!(si.get(q0), sc.get(q0), "step {step}");
        toggled |= sc.get(q0) != 0;
    }
    assert!(toggled, "toggler must actually toggle on the compiled path");
}

#[test]
fn set_on_folded_net_is_a_noop_not_an_alias_write() {
    // `buf` folds onto input `a`; driving the folded net must NOT clobber
    // the surviving input on the compiled path (the oracle's next eval
    // would overwrite such a write anyway).
    let mut n = Netlist::new("t");
    let a = n.add_input("a", 1)[0];
    let buf = n.fresh();
    n.cells.push(Cell::Buf { a, y: buf });
    let y = n.inv(buf);
    n.add_output("y", vec![y]);
    let mut si = Sim::from_plan(Arc::new(SimPlan::new(&n)));
    let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
    for s in [&mut si, &mut sc] {
        s.set(a, 0xF0F0);
        s.set(buf, 0x0F0F); // interpreted: overwritten at eval; compiled: no-op
        s.eval();
    }
    assert_eq!(si.get(y), sc.get(y));
    assert_eq!(sc.get(a), 0xF0F0, "survivor input must not be clobbered");
}

#[test]
fn reset_semantics_match_after_partial_runs() {
    // Clock both paths through garbage cycles, reset mid-flight, and
    // compare every observable on every lane at each stage.
    let m = rand_model(23, 6, 3, 3);
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let net = &circ.netlist;
    let mut si = Sim::from_plan(Arc::new(SimPlan::new(net)));
    let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(net)));
    let mut r = Rng::new(9);
    for round in 0..3 {
        for _ in 0..5 {
            for port in &net.inputs {
                for &bit in &port.bits {
                    let v = r.next_u64();
                    si.set(bit, v);
                    sc.set(bit, v);
                }
            }
            si.step();
            sc.step();
            assert!(outputs_equal(net, &si, &sc), "round {round} step");
        }
        si.reset();
        sc.reset();
        assert!(outputs_equal(net, &si, &sc), "round {round} reset");
    }
}
