//! Quickstart: load one dataset's artifacts, run the paper's automated
//! framework end-to-end on it, and print the resulting design points.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use printed_mlp::coordinator::{run_dataset, PipelineConfig};
use printed_mlp::data::ArtifactStore;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::discover();
    if !store.has("spectf") {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // Smaller NSGA budget for a fast first run; the full harness uses the
    // defaults (pop 40 × 30 generations).
    let mut cfg = PipelineConfig::default();
    cfg.nsga.pop_size = 16;
    cfg.nsga.generations = 12;
    cfg.cache = false;

    let out = run_dataset(&store, "spectf", &cfg)?;

    println!("dataset          : {}", out.name);
    println!(
        "RFP              : kept {}/{} features ({:.0}% retention, {} evals)",
        out.rfp.kept,
        out.rfp.order.len(),
        out.rfp.retention() * 100.0,
        out.rfp.evals
    );
    for (drop, sel) in &out.selections {
        println!(
            "NSGA @ {:.0}% drop : {} of {} neurons single-cycle (train acc {:.3})",
            drop * 100.0,
            sel.n_approx,
            sel.approx_mask.len(),
            sel.accuracy
        );
    }
    println!(
        "\n{:<14} {:>10} {:>10} {:>8} {:>10} {:>9}",
        "design", "area cm²", "power mW", "cycles", "energy mJ", "test acc"
    );
    for d in [&out.comb, &out.sota, &out.ours]
        .into_iter()
        .chain(out.hybrids.iter().map(|(_, d)| d))
    {
        println!(
            "{:<14} {:>10.1} {:>10.1} {:>8} {:>10.2} {:>9.3}",
            d.arch, d.report.area_cm2, d.report.power_mw, d.cycles, d.energy_mj, d.test_acc
        );
    }
    println!(
        "\nours vs seq[16]: {:.1}× area, {:.1}× power (paper Table 1: 3.8× / 5.5× for SPECTF)",
        out.sota.report.area_cm2 / out.ours.report.area_cm2,
        out.sota.report.power_mw / out.ours.report.power_mw
    );
    Ok(())
}
