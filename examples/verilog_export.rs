//! Export synthesizable structural Verilog for every architecture of one
//! dataset, together with the synthesis-lite report — the framework's
//! hand-off point to a real EDA flow (the paper feeds Synopsys DC).
//!
//! ```bash
//! cargo run --release --example verilog_export [dataset] [outdir]
//! ```

use printed_mlp::circuits::{combinational, hybrid, seq_multicycle, seq_sota};
use printed_mlp::data::ArtifactStore;
use printed_mlp::model::importance;
use printed_mlp::netlist::verilog;
use printed_mlp::tech;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("spectf");
    let outdir = args.get(1).map(|s| s.as_str()).unwrap_or("artifacts/results/rtl");
    std::fs::create_dir_all(outdir)?;

    let store = ArtifactStore::discover();
    let model = store.model(name)?;
    let ds = store.dataset(name)?;
    let active: Vec<usize> = (0..model.features).collect();
    let fm = vec![1u8; model.features];
    let tables = importance::approx_tables(&model, &ds.train.xs, ds.train.len(), &fm);
    let approx: Vec<bool> = (0..model.hidden).map(|h| h % 2 == 0).collect();

    let designs: Vec<(&str, printed_mlp::netlist::Netlist)> = vec![
        ("comb", combinational::generate(&model, &active).netlist),
        ("seq_sota", seq_sota::generate(&model, &active).netlist),
        ("multicycle", seq_multicycle::generate(&model, &active).netlist),
        ("hybrid", hybrid::generate(&model, &active, &approx, &tables).netlist),
    ];

    println!(
        "{:<12} {:>9} {:>8} {:>11} {:>10} {:>7}",
        "design", "cells", "DFFs", "area cm²", "power mW", "depth"
    );
    for (label, netlist) in designs {
        let rep = tech::report(&netlist);
        let path = format!("{outdir}/{name}_{label}.v");
        std::fs::write(&path, verilog::emit(&netlist))?;
        println!(
            "{:<12} {:>9} {:>8} {:>11.1} {:>10.1} {:>7}   -> {path}",
            label, rep.n_cells, rep.n_dffs, rep.area_cm2, rep.power_mw, rep.logic_depth
        );
    }
    Ok(())
}
