//! Neuron-approximation design-space exploration (§3.2.3, Fig. 7): runs
//! NSGA-II on one dataset and dumps the full Pareto front —
//! (#single-cycle neurons vs accuracy) — plus the circuit-level area of
//! each frontier point, so you can see the abstract objective (neuron
//! count) tracking real area.
//!
//! ```bash
//! cargo run --release --example approx_explore [dataset] [pop] [gens]
//! ```

use printed_mlp::approx;
use printed_mlp::circuits::{hybrid, seq_multicycle};
use printed_mlp::data::ArtifactStore;
use printed_mlp::model::ApproxTables;
use printed_mlp::nsga::NsgaConfig;
use printed_mlp::runtime::{Engine, PjrtEvaluator, BATCH_THROUGHPUT};
use printed_mlp::tech;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("har");
    let pop: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let gens: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(30);

    let store = ArtifactStore::discover();
    let model = store.model(name)?;
    let ds = store.dataset(name)?;
    let engine = Engine::cpu()?;
    let eval = PjrtEvaluator::new(
        &engine,
        &store.hlo_path(name, BATCH_THROUGHPUT),
        &model,
        BATCH_THROUGHPUT,
    )?;

    let fit = ds.train.head(512);
    let fm = vec![1u8; model.features];
    let tables = approx::build_tables(&model, &fit.xs, fit.len(), &fm);
    let baseline = eval.accuracy(&fit, &fm, &vec![0u8; model.hidden], &ApproxTables::disabled(model.hidden))?;
    println!("{name}: H={} baseline train acc {baseline:.3}; NSGA pop {pop} × {gens} generations", model.hidden);

    let cfg = NsgaConfig {
        pop_size: pop,
        generations: gens,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let mut front = approx::explore(model.hidden, &cfg, |mask| {
        eval.accuracy(&fit, &fm, mask, &tables).expect("PJRT eval")
    });
    front.sort_by(|a, b| a.objectives[0].partial_cmp(&b.objectives[0]).unwrap());
    println!("explored in {:.1}s; Pareto front:", t0.elapsed().as_secs_f64());

    let active: Vec<usize> = (0..model.features).collect();
    let exact_area = tech::report(&seq_multicycle::generate(&model, &active).netlist).area_cm2;
    println!(
        "{:>8} {:>10} {:>12} {:>10}",
        "#approx", "train acc", "area cm²", "area gain"
    );
    for ind in &front {
        let approx_b: Vec<bool> = ind.genome.clone();
        let circ = hybrid::generate(&model, &active, &approx_b, &tables);
        let area = tech::report(&circ.netlist).area_cm2;
        println!(
            "{:>8} {:>10.3} {:>12.1} {:>9.2}×",
            ind.objectives[0], ind.objectives[1], area, exact_area / area
        );
    }
    for drop in [0.01, 0.02, 0.05] {
        let sel = approx::select(&front, baseline, drop);
        println!(
            "selected @ {:.0}% drop: {} neurons, train acc {:.3}",
            drop * 100.0,
            sel.n_approx,
            sel.accuracy
        );
    }
    Ok(())
}
