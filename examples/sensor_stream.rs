//! Multi-sensory streaming demo: wearable-style sensors stream frames at a
//! configurable rate into the Rust coordinator, which dynamically batches
//! them onto the AOT-compiled PJRT classifier and reports latency
//! percentiles and throughput — the deployment story of the paper's
//! intro, with Python nowhere on the request path.
//!
//! ```bash
//! cargo run --release --example sensor_stream [dataset] [rate_hz] [secs]
//! ```

use printed_mlp::coordinator::serve::{run, ServeConfig};
use printed_mlp::data::ArtifactStore;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = ServeConfig::default();
    if let Some(d) = args.first() {
        cfg.dataset = d.clone();
    }
    if let Some(r) = args.get(1).and_then(|s| s.parse().ok()) {
        cfg.rate_hz = r;
    }
    if let Some(s) = args.get(2).and_then(|s| s.parse::<f64>().ok()) {
        cfg.duration = std::time::Duration::from_secs_f64(s);
    }

    let store = ArtifactStore::discover();
    println!(
        "streaming {} at {:.0} frames/s from {} sensors for {:.1}s (batch wait {:?})",
        cfg.dataset,
        cfg.rate_hz,
        cfg.sensors,
        cfg.duration.as_secs_f64(),
        cfg.max_wait
    );
    let rep = run(&store, &cfg)?;
    println!(
        "served {} requests in {} batches (mean batch {:.1})",
        rep.requests, rep.batches, rep.mean_batch
    );
    println!("throughput: {:.0} req/s", rep.throughput_rps);
    println!("latency   : p50 {:.2} ms, p99 {:.2} ms", rep.p50_ms, rep.p99_ms);
    println!("accuracy  : {:.3}", rep.accuracy);
    Ok(())
}
