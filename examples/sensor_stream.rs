//! Multi-sensory streaming demo: wearable-style sensors stream frames at
//! a configurable rate into the multi-tenant model server, which hosts
//! one model per dataset behind per-model dynamic-batching queues and
//! reports per-model latency percentiles, shed counts, and throughput —
//! the deployment story of the paper's intro, with Python nowhere on the
//! request path.
//!
//! ```bash
//! cargo run --release --example sensor_stream [datasets] [rate_hz] [secs] [scenario]
//! # e.g. against real artifacts:
//! cargo run --release --example sensor_stream spectf,arrhythmia,gas 2000 3 fanin
//! # or artifact-free with synthetic models:
//! cargo run --release --example sensor_stream synthetic 5000 1 bursty
//! ```

use printed_mlp::data::ArtifactStore;
use printed_mlp::server::{run, ServeConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = ServeConfig::default();
    if let Some(d) = args.first() {
        if d == "synthetic" {
            cfg.synthetic = true;
            cfg.datasets = vec!["syn0".into(), "syn1".into(), "syn2".into()];
        } else {
            cfg.datasets = d.split(',').map(|s| s.trim().to_string()).collect();
        }
    }
    if let Some(r) = args.get(1).and_then(|s| s.parse().ok()) {
        cfg.rate_hz = r;
    }
    if let Some(s) = args.get(2).and_then(|s| s.parse::<f64>().ok()) {
        cfg.duration = std::time::Duration::from_secs_f64(s);
    }
    if let Some(sc) = args.get(3) {
        cfg.scenario = sc.parse()?;
    }

    let store = ArtifactStore::discover();
    println!(
        "streaming {} [{}] at {:.0} frames/s from {} sensors for {:.1}s (batch wait {:?})",
        cfg.datasets.join("+"),
        cfg.scenario.label(),
        cfg.rate_hz,
        cfg.sensors,
        cfg.duration.as_secs_f64(),
        cfg.max_wait
    );
    let rep = run(&store, &cfg)?;
    for m in &rep.models {
        println!(
            "  {:<12} {:>6} req | shed {:>4} | {:>7.0} req/s | mean batch {:>5.1} | \
             p50 {:>6.2} ms | p99 {:>6.2} ms | acc {:.3}",
            m.name,
            m.requests,
            m.shed,
            m.throughput_rps,
            m.mean_batch,
            m.p50_ms,
            m.p99_ms,
            m.accuracy
        );
    }
    println!(
        "total: {} requests ({} shed) at {:.0} req/s on {} workers [{}]",
        rep.total_requests(),
        rep.total_shed(),
        rep.total_rps(),
        rep.workers,
        rep.backend
    );
    Ok(())
}
