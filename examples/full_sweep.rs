//! End-to-end driver (the EXPERIMENTS.md run): executes the complete
//! framework — PJRT-backed RFP + NSGA-II, all four circuit architectures,
//! gate-level validation — over all seven paper datasets and regenerates
//! every table and figure of the evaluation (§4).
//!
//! ```bash
//! make artifacts && cargo run --release --example full_sweep
//! ```
//!
//! Writes `artifacts/results/report.md` + one CSV per table/figure.

use std::time::Instant;

use printed_mlp::coordinator::{run_pipeline, PipelineConfig};
use printed_mlp::data::ArtifactStore;
use printed_mlp::report;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::discover();
    let cfg = PipelineConfig::default();
    for d in &cfg.datasets {
        if !store.has(d) {
            eprintln!("artifacts for {d} missing — run `make artifacts` first");
            std::process::exit(1);
        }
    }

    println!(
        "running full pipeline: {} datasets, {} threads, NSGA pop {} × {} generations",
        cfg.datasets.len(),
        cfg.threads,
        cfg.nsga.pop_size,
        cfg.nsga.generations
    );
    let t0 = Instant::now();
    let outs = run_pipeline(&store, &cfg)?;
    println!("pipeline done in {:.1}s\n", t0.elapsed().as_secs_f64());

    let md = report::full_report(&outs, &store.results_dir())?;
    println!("{md}");
    println!(
        "wrote {} and per-experiment CSVs",
        store.results_dir().join("report.md").display()
    );
    Ok(())
}
